"""A/B benchmark: fp32 vs bf16 precision policy over kernel x impl.

Four tables (``name,us_per_call,derived`` rows like every benchmark):

  ps/gemm/<shape>/<kernel>/<prec>   y = x @ w.T single device: the MXU
                                    rate claim (bf16 ~2x on real TPU)
  ps/ring/<impl>/<kernel>/<prec>    jigsaw_linear on an 8-way host mesh:
                                    wall clock per call, both precisions
  ps/wire/<impl>                    lowered-HLO wire bytes fp32 vs bf16
                                    (must be ratio 0.5 -- ASSERTED; read
                                    pre-optimization because the CPU
                                    backend widens bf16 collectives)
  ps/schedule/<impl>/<prec>         analytic per-hop accounting
                                    (comm_schedule_jigsaw_1d): bf16
                                    halves bytes_per_hop at identical
                                    flops_per_hop -> 2x overlap headroom

On CPU the wall-clock rows track code paths, not performance (pallas is
interpret mode, bf16 is emulated); the asserted wire ratio and the
analytic schedule carry the perf claims.  The backend is recorded in
every derived field.

Writes results/precision_sweep.csv unless --tiny (CI smoke) or
--no-write.
"""
import argparse
import os
import sys
import time

if __package__ in (None, ""):   # `python benchmarks/precision_sweep.py`
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit, run_subprocess_devices

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "precision_sweep.csv")

RING_CODE = """
import time, jax, jax.numpy as jnp
from repro.core.api import JigsawConfig, linear_apply, linear_init
from repro.launch.analysis import collective_stats
from repro.launch.mesh import make_host_mesh

B, T, D, M, ITERS = {b}, {t}, {d}, {m}, {iters}
mesh = make_host_mesh(model=8, data=1)
params = linear_init(jax.random.PRNGKey(0), D, M)
x = jax.random.normal(jax.random.PRNGKey(1), (B, T, D))
with jax.set_mesh(mesh):
    for impl in ["rs", "ring_chunked"]:
        wire = {{}}
        for prec, cd in [("fp32", None), ("bf16", jnp.bfloat16)]:
            for kern in (["xla", "pallas"] if {with_pallas} else ["xla"]):
                cfg = JigsawConfig(impl=impl, kernel=kern,
                                   compute_dtype=cd)
                fn = jax.jit(lambda p, v, c=cfg: linear_apply(p, v, c))
                if kern == "xla":
                    low = fn.lower(params, x)
                    st = collective_stats(
                        low.compiler_ir(dialect="hlo").as_hlo_text())
                    wire[prec] = st.total_bytes
                fn(params, x).block_until_ready()
                t0 = time.time()
                for _ in range(ITERS):
                    fn(params, x).block_until_ready()
                us = (time.time() - t0) / ITERS * 1e6
                print(f"RING {{impl}} {{kern}} {{prec}} {{us:.0f}}")
        ratio = wire["bf16"] / wire["fp32"]
        assert abs(ratio - 0.5) < 1e-6, (impl, wire)
        print(f"WIRE {{impl}} {{wire['fp32']:.0f}} {{wire['bf16']:.0f}} "
              f"{{ratio:.3f}}")
"""


def _timed(fn, *args, iters=5):
    import jax
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters


def run(tiny: bool = False):
    import jax
    import jax.numpy as jnp
    from repro.core.jigsaw import comm_schedule_jigsaw_1d
    from repro.kernels import ops
    from repro.launch import analysis as A

    backend = jax.default_backend()
    mode = "compiled" if backend == "tpu" else "cpu-interpret"
    iters = 2 if tiny else 5
    rows = []

    # --- single-device GEMM A/B: fp32 vs bf16, xla vs pallas ----------
    shapes = [(128, 128, 256)] if tiny else [(256, 512, 1024),
                                             (512, 512, 2048)]
    for m, k, n in shapes:
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        flops = 2.0 * m * k * n
        for prec, dt in (("fp32", jnp.float32), ("bf16", jnp.bfloat16)):
            x = jax.random.normal(k1, (m, k)).astype(dt)
            w = (jax.random.normal(k2, (n, k)) * 0.05).astype(dt)

            def xla_gemm(x, w):
                return jax.lax.dot_general(
                    x, w, (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32).astype(x.dtype)

            t_x = _timed(jax.jit(xla_gemm), x, w, iters=iters)
            t_p = _timed(lambda x, w: ops.matmul(x, w, None), x, w,
                         iters=iters)
            for kern, t in (("xla", t_x), ("pallas", t_p)):
                rows.append((f"ps/gemm/{m}x{k}x{n}/{kern}/{prec}",
                             int(t * 1e6),
                             f"gflops={flops / t / 1e9:.1f}|mode={mode}"))

    # --- ring sweep on an 8-way host mesh (subprocess) ----------------
    b_, t_, d_, m_ = (2, 32, 128, 128) if tiny else (4, 256, 512, 512)
    out = run_subprocess_devices(
        RING_CODE.format(b=b_, t=t_, d=d_, m=m_, iters=iters,
                         with_pallas=not tiny), 8)
    for line in out.splitlines():
        if line.startswith("RING"):
            _, impl, kern, prec, us = line.split()
            rows.append((f"ps/ring/{impl}/{kern}/{prec}", int(float(us)),
                         f"shape={b_}x{t_}x{d_}x{m_}|mode={mode}"))
        elif line.startswith("WIRE"):
            _, impl, f32b, bf16b, ratio = line.split()
            rows.append((f"ps/wire/{impl}", 0,
                         f"fp32_bytes={f32b}|bf16_bytes={bf16b}"
                         f"|ratio={ratio}|asserted=0.5"))

    # --- analytic per-hop schedule: bf16 doubles overlap headroom -----
    tokens, m, d, p = 4096, 4320, 4320, 8
    for prec, dtype_bytes in (("fp32", 4), ("bf16", 2)):
        for chunked in (False, True):
            cs = comm_schedule_jigsaw_1d(tokens, m, d // p, p,
                                         dtype_bytes=dtype_bytes,
                                         chunked=chunked)
            ratio = cs.overlap_ratio(A.ICI_BW, A.PEAK_FLOPS_BF16)
            rows.append((f"ps/schedule/{cs.scheme}/{prec}", 0,
                         f"hops={cs.hops}"
                         f"|bytes_per_hop={cs.bytes_per_hop:.0f}"
                         f"|flops_per_hop={cs.flops_per_hop:.2e}"
                         f"|overlap_ratio={ratio:.2f}"))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: small shapes, no results/ write")
    ap.add_argument("--no-write", action="store_true")
    ap.add_argument("--out", default=RESULTS)
    args = ap.parse_args()
    rows = run(tiny=args.tiny)
    emit(rows)
    if not args.tiny and not args.no_write:
        with open(args.out, "w") as f:
            f.write("name,us_per_call,derived\n")
            for r in rows:
                f.write(",".join(str(x) for x in r) + "\n")
        print(f"[precision_sweep] wrote {args.out}")


if __name__ == "__main__":
    main()
