"""Paper Table 1: the WeatherMixer scaling zoo.

Validates our configs against the paper's own numbers: parameter counts
(paper's "Params (mil)" column) and TFLOPs per forward pass (the defining
column -- workloads 0.25..64 TFLOPs).  This is the §Paper-claims check
that WeatherMixer's workload scales linearly in input size and that our
FLOPs model reproduces the paper's accounting.
"""
from benchmarks.common import Timer, emit

# paper Table 1: model # -> (TFLOPs/forward, params (mil))
PAPER = {1: (0.25, 60), 2: (0.5, 230), 3: (1, 240), 4: (2, 260),
         5: (4, 500), 6: (8, 980), 7: (16, 1400), 8: (32, 2000),
         9: (64, 2600)}


def run():
    from repro.configs.weathermixer_1b import ZOO
    from repro.launch import analysis as A

    rows = []
    with Timer() as t:
        for num, cfg in ZOO.items():
            flops_fwd = sum(A.flops_forward(cfg, 1, 0).values())
            tflops = flops_fwd / 1e12
            params_m = cfg.param_count() / 1e6
            paper_tf, paper_pm = PAPER[num]
            rows.append((f"table1/model{num}", 0,
                         f"tflops_fwd={tflops:.2f}|paper={paper_tf}"
                         f"|params_M={params_m:.0f}|paper_M={paper_pm}"
                         f"|flops_ratio={tflops / paper_tf:.2f}"))
    rows.append(("table1/zoo_total", int(t.seconds * 1e6), "n_models=9"))
    return rows


if __name__ == "__main__":
    emit(run())
