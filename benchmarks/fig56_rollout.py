"""Paper Figs. 5/6: one-step skill + rolled-out stability.

Fig 5 analog: latitude-weighted RMSE of the trained model vs the
persistence baseline (output = input) on held-out synthetic data -- the
model must beat persistence to have learned dynamics.
Fig 6 analog: RMSE over a 5-step rollout, with and without the paper's
randomized-rollout fine-tuning (§6: processor repeated r times).
"""
import numpy as np

from benchmarks.common import Timer, emit


def run():
    import jax
    import jax.numpy as jnp
    from repro.configs.registry import get_config
    from repro.data.weather import WeatherDataConfig, WeatherDataset
    from repro.launch import shapes as SH
    from repro.launch.train import train
    from repro.models import registry as M
    from repro.train import loss as losses

    rows = []
    cfg = get_config("weathermixer-1b").reduced()
    jcfg = SH.jigsaw_for(cfg)
    dcfg = WeatherDataConfig(lat=cfg.wm_lat, lon=cfg.wm_lon,
                             channels=cfg.wm_channels, seed=0)
    ds = WeatherDataset(dcfg)
    lat_w = losses.latitude_weights(cfg.wm_lat)

    def rmse(pred, tgt):
        return float(jnp.mean(losses.latitude_weighted_rmse(
            jnp.asarray(pred), jnp.asarray(tgt), lat_w)))

    with Timer() as t1:
        _, params = train("weathermixer-1b", steps=80, batch=4,
                          reduced=True, lr=2e-3, log_every=80)
    # --- Fig 5: one-step skill vs persistence
    b = ds.sample_batch(2000, 4)
    pred, _ = M.apply(params, {"fields": jnp.asarray(b["fields"])}, cfg,
                      jcfg)
    model_rmse = rmse(pred, b["target"])
    persist_rmse = rmse(b["fields"], b["target"])
    rows.append(("fig5/one_step", int(t1.seconds * 1e6),
                 f"model_rmse={model_rmse:.4f}"
                 f"|persistence_rmse={persist_rmse:.4f}"
                 f"|beats_persistence={model_rmse < persist_rmse}"))

    # --- Fig 6: rollout stability, base vs rollout-fine-tuned
    with Timer() as t2:
        # fine-tune FROM the one-step-trained model (paper SS6: rollout
        # fine-tuning follows base training)
        _, params_ft = train("weathermixer-1b", steps=40, batch=4,
                             reduced=True, lr=3e-4, rollout=3,
                             log_every=40, init_params=params)

    def rollout_rmse(p, n=5):
        x = jnp.asarray(b["fields"])
        errs = []
        ds_t = ds
        cur_t = 0.0
        for step in range(n):
            x, _ = M.apply(p, {"fields": x}, cfg, jcfg)
            cur_t += dcfg.dt_phase
            tgt = ds_t._eval(np.arange(4) + 2000 * 4, np.arange(cfg.wm_lat),
                             np.arange(cfg.wm_lon),
                             np.arange(cfg.wm_channels), cur_t)
            errs.append(rmse(x, tgt))
        return errs

    base_errs = rollout_rmse(params)
    ft_errs = rollout_rmse(params_ft)
    rows.append(("fig6/rollout", int(t2.seconds * 1e6),
                 "base=" + "/".join(f"{e:.3f}" for e in base_errs)
                 + "|finetuned=" + "/".join(f"{e:.3f}" for e in ft_errs)
                 + f"|ft_better_at_5={ft_errs[-1] < base_errs[-1]}"))
    return rows


if __name__ == "__main__":
    emit(run())
