"""Paper Fig. 4 / §6.2.1 "equivalent usage": at a FIXED compute budget,
assigning devices to model parallelism instead of data parallelism lowers
the global batch -> more optimizer steps on the same sample budget ->
better convergence (large-batch-effect mitigation).

We reproduce the mechanism exactly: the same total number of samples
seen, with global batch 8 (1-way analog), 4 (2-way) and 2 (4-way).
"""
from benchmarks.common import Timer, emit


def run(sample_budget: int = 320):
    from repro.launch.train import train

    rows = []
    finals = {}
    for way, gb in [("1way", 8), ("2way", 4), ("4way", 2)]:
        steps = sample_budget // gb
        with Timer() as t:
            hist, _ = train("weathermixer-1b", steps=steps, batch=gb,
                            reduced=True, lr=1e-3, log_every=steps - 1)
        finals[way] = hist[-1]["loss"]
        rows.append((f"fig4/{way}", int(t.seconds * 1e6 / steps),
                     f"global_batch={gb}|steps={steps}"
                     f"|final_loss={hist[-1]['loss']:.4f}"))
    claim = finals["4way"] <= finals["2way"] <= finals["1way"] * 1.02
    rows.append(("fig4/large_batch_mitigation", 0,
                 f"smaller_batch_converges_lower={claim}"))
    return rows


if __name__ == "__main__":
    emit(run())
