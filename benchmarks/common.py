"""Shared helpers for the benchmark harness."""
import json
import os
import subprocess
import sys
import time

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0


def run_subprocess_devices(code: str, n_devices: int, timeout=560) -> str:
    """Run a python snippet with N host-emulated devices; returns stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.abspath(SRC)
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if res.returncode != 0:
        raise RuntimeError(f"subprocess failed:\n{res.stderr[-2000:]}")
    return res.stdout


def emit(rows, header=("name", "us_per_call", "derived")):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
