"""§Paper-claims: communication-volume comparison Jigsaw vs Megatron-LM.

Paper claim: Jigsaw needs NO weight allgather/broadcast (zero redundancy)
and completes each linear with partial-sum exchanges.  We verify on real
compiled HLO (4-way host mesh): count collective kinds and bytes for one
forward pass of an MLP pair under (a) Jigsaw-1D rs, (b) Jigsaw ring,
(c) the chunked ring, (d) Megatron-style (allreduce), (e) GSPMD-derived.

The chunked ring moves EXACTLY the same bytes as the monolithic ring
(asserted on the compiled HLO below); the per-hop table shows what it
changes instead -- the GEMM work left pending while each hop's send is
in flight (comm_schedule_jigsaw_1d).

Precision policy (ISSUE 5): the bf16 compute policy must HALVE the ring
bytes -- every ppermute chunk ships compute_dtype.  Asserted on the
PRE-optimization HLO (``compiler_ir('hlo')``): that is where the wire
dtype is a program property; backend legalization may rewrite it (the
CPU backend widens bf16 collectives to f32 because the host has no
native bf16 -- on TPU the compiled module keeps the bf16 wire).
"""
from benchmarks.common import emit, run_subprocess_devices

CODE = """
import jax, jax.numpy as jnp, numpy as np
from repro.core.api import JigsawConfig, mlp_apply, mlp_init
from repro.launch.mesh import make_host_mesh
from repro.launch.analysis import collective_stats

mesh = make_host_mesh(model=4, data=1)
params = mlp_init(jax.random.PRNGKey(0), 512, 2048, 512, bias=False)
x = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 512))
for impl in ["rs", "ring", "ring_chunked", "ring_fused", "allreduce",
             "gspmd"]:
    cfg = JigsawConfig(impl=impl)
    with jax.set_mesh(mesh):
        comp = jax.jit(lambda p, v: mlp_apply(p, v, cfg)).lower(
            params, x).compile()
    st = collective_stats(comp.as_text())
    print(f"IMPL {impl} bytes {st.total_bytes:.0f} counts {st.counts}")

# precision A/B on the unoptimized HLO: bf16 wire == 0.5x fp32 wire
for impl in ["rs", "ring", "ring_chunked", "ring_fused"]:
    res = {}
    for prec, cd in [("fp32", None), ("bf16", jnp.bfloat16)]:
        cfg = JigsawConfig(impl=impl, compute_dtype=cd)
        with jax.set_mesh(mesh):
            low = jax.jit(lambda p, v: mlp_apply(p, v, cfg)).lower(
                params, x)
        st = collective_stats(low.compiler_ir(dialect="hlo").as_hlo_text())
        res[prec] = st.total_bytes
    ratio = res["bf16"] / res["fp32"]
    assert abs(ratio - 0.5) < 1e-6, (impl, res)
    print(f"PREC {impl} fp32 {res['fp32']:.0f} bf16 {res['bf16']:.0f} "
          f"ratio {ratio:.3f}")
"""


def run():
    from repro.core.jigsaw import (comm_schedule_jigsaw_1d,
                                   comm_volume_jigsaw_1d,
                                   comm_volume_megatron_pair)
    from repro.launch import analysis as A

    out = run_subprocess_devices(CODE, 4)
    rows = []
    hlo_bytes = {}
    for line in out.splitlines():
        if line.startswith("IMPL"):
            parts = line.split()
            impl, bts = parts[1], float(parts[3])
            hlo_bytes[impl] = bts
            rows.append((f"comm/{impl}", 0,
                         f"hlo_bytes_per_dev={bts:.0f}"))
    an_j = comm_volume_jigsaw_1d(256, 512, 4).bytes_per_device * 2  # 2 linears
    an_m = comm_volume_megatron_pair(256, 512, 4).bytes_per_device
    rows.append(("comm/analytic", 0,
                 f"jigsaw1d={an_j:.0f}|megatron_pair={an_m:.0f}"
                 f"|jigsaw_vs_megatron={an_j / an_m:.2f}"))

    # precision A/B (asserted in-subprocess): bf16 wire == 0.5x fp32
    for line in out.splitlines():
        if line.startswith("PREC"):
            parts = line.split()
            rows.append((f"comm/precision/{parts[1]}", 0,
                         f"fp32_bytes={parts[3]}|bf16_bytes={parts[5]}"
                         f"|ratio={parts[7]}"))

    # chunked/fused-ring per-hop accounting: same volume, overlap
    # exposed (chunked) or enforced in-kernel (fused).  Shapes mirror the
    # HLO experiment (fc1 of the MLP pair, p=4); the bf16 rows halve
    # bytes_per_hop at the same flops_per_hop, doubling the per-hop
    # overlap headroom.
    same = ("ring" in hlo_bytes and "ring_chunked" in hlo_bytes
            and hlo_bytes["ring"] == hlo_bytes["ring_chunked"])
    rows.append(("comm/ring_vs_chunked", 0,
                 f"hlo_bytes_equal={same}"))
    # the fused kernel's CPU fallback lowers to the same chunk-granular
    # ppermute hops: compiled collective bytes must match the ring's.
    same_f = ("ring" in hlo_bytes and "ring_fused" in hlo_bytes
              and hlo_bytes["ring"] == hlo_bytes["ring_fused"])
    rows.append(("comm/ring_vs_fused", 0,
                 f"hlo_bytes_equal={same_f}"))
    assert same_f, ("ring_fused must move exactly the ring's bytes",
                    hlo_bytes)
    for prec, dtype_bytes in (("fp32", 4), ("bf16", 2)):
        for impl in ("ring", "ring_chunked", "ring_fused"):
            cs = comm_schedule_jigsaw_1d(256, 2048, 512 // 4, 4,
                                         dtype_bytes=dtype_bytes,
                                         impl=impl)
            rows.append((f"comm/schedule/{cs.scheme}/{prec}", 0,
                         f"hops={cs.hops}"
                         f"|bytes_per_hop={cs.bytes_per_hop:.0f}"
                         f"|flops_per_hop={cs.flops_per_hop:.2e}"
                         f"|bytes_per_dev={cs.bytes_per_device:.0f}"
                         f"|overlap_ratio="
                         f"{cs.overlap_ratio(A.ICI_BW, A.PEAK_FLOPS_BF16):.2f}"))
    return rows


if __name__ == "__main__":
    emit(run())
