"""Input-pipeline overlap benchmark (the paper's §5 data-loading claim as
a measurement): step time of the SAME reduced-WM training run through
``TrainEngine`` with

  * ``sync-full``  -- legacy path: the host generates the full global
                      batch between steps (input serializes with compute);
  * ``sharded``    -- each rank's (lon x channel, batch-row) partition
                      only, synchronous (I/O shrinks ∝ 1/ranks);
  * ``sharded+pf`` -- sharded reads + background-thread double-buffered
                      prefetch (input overlaps device compute).

Host-emulated mesh (model=4, data=2); absolute numbers are CPU
artifacts, the *ratios* are the contribution.  A large grid is used so
host-side generation is a visible fraction of the step.
"""
from benchmarks.common import emit, run_subprocess_devices

MEASURE_CODE = """
from repro.configs.registry import get_config
from repro.launch.engine import EngineConfig, TrainEngine

cfg = get_config("weathermixer-1b").reduced().replace(
    scheme="1d", wm_lat=96, wm_lon=192, d_model=128,
    wm_d_tok=256, wm_d_ch=128)
eng = TrainEngine("weathermixer-1b", reduced=False, config_override=cfg,
                  mesh_model=4, mesh_data=2, scheme="1d",
                  config=EngineConfig(steps=12, batch=8,
                                      pipeline={mode!r},
                                      prefetch={prefetch}))
secs = eng.benchmark(steps=8, warmup=2)
gen = sum(eng.pipeline.stats.generated_bytes.values())
print("SECONDS", secs)
print("GENBYTES", gen)
"""


def run():
    rows = []
    base = None
    for name, mode, prefetch in [("sync-full", "sync-full", 0),
                                 ("sharded", "sharded", 0),
                                 ("sharded+prefetch", "sharded", 2)]:
        out = run_subprocess_devices(
            MEASURE_CODE.format(mode=mode, prefetch=prefetch), n_devices=8)
        secs = float([l for l in out.splitlines()
                      if l.startswith("SECONDS")][0].split()[1])
        gen = int([l for l in out.splitlines()
                   if l.startswith("GENBYTES")][0].split()[1])
        base = base or secs
        rows.append((f"pipeline/{name}", int(secs * 1e6),
                     f"speedup_vs_sync={base / secs:.2f}"
                     f"|host_gen_MB={gen / 1e6:.1f}"))
    return rows


if __name__ == "__main__":
    emit(run())
