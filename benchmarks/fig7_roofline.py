"""Paper Fig. 7: roofline — I/O-bandwidth-limited vs computation-
communication-limited regimes for 1-, 2-, 4-way Jigsaw across the Table-1
zoo.

TPU adaptation: the paper measures achieved FLOP/s on A100s; we derive
the same two-regime structure analytically for the v5e target from our
FLOPs model + the domain-parallel I/O model (data/weather.py):

  t_io(n)      = sample_bytes / (n * DISK_BW)   (domain parallelism
                                                 divides I/O by n -- §5)
  t_compute    = flops_fwd_bwd / (n * PEAK)
  t_collective = jigsaw comm volume / ICI_BW

achieved FLOP/s = total_flops / max(t_io, t_compute + t_collective) / n.
The paper's claims checked here: (1) small models are I/O-bound and
parallel models get *superscalar* throughput from partitioned loading;
(2) at large model size the 2-way model stays near the 1-way compute
roofline (overlapped communication); (3) peak fractions.
"""
from benchmarks.common import emit

DISK_BW = 2e9          # bytes/s per host stream (HoreKa-like Lustre share)
SAMPLE_BYTES = 4 * 721 * 1440 * 69   # one 0.25-deg f32 sample (paper)


def run():
    from repro.configs.weathermixer_1b import ZOO
    from repro.core.jigsaw import (comm_volume_jigsaw_1d,
                                   comm_volume_jigsaw_2d)
    from repro.launch import analysis as A

    rows = []
    for num, cfg in sorted(ZOO.items()):
        flops_fwd = sum(A.flops_forward(cfg, 1, 0).values())
        flops = 3 * flops_fwd                     # fwd + bwd
        t_tokens = (cfg.wm_lat // cfg.wm_patch) * (cfg.wm_lon // cfg.wm_patch)
        for way in (1, 2, 4):
            t_io = SAMPLE_BYTES / (way * DISK_BW)
            t_comp = flops / (way * A.PEAK_FLOPS_BF16)
            if way == 1:
                t_coll = 0.0
            elif way == 2:
                # 1-D jigsaw on every linear: RS of each layer's outputs
                v = 3 * (comm_volume_jigsaw_1d(t_tokens, cfg.wm_d_ch, way)
                         .bytes_per_device * 2 * cfg.n_layers)
                t_coll = v / A.ICI_BW
            else:
                v = 3 * (comm_volume_jigsaw_2d(t_tokens, cfg.wm_d_ch, 2)
                         .bytes_per_device * 2 * cfg.n_layers)
                t_coll = v / A.ICI_BW
            t_step = max(t_io, t_comp + t_coll)
            achieved = flops / t_step / way
            frac = achieved / A.PEAK_FLOPS_BF16
            regime = "io" if t_io > t_comp + t_coll else "compute-comm"
            rows.append((f"fig7/model{num}/{way}way",
                         int(t_step * 1e6),
                         f"tflops_per_dev={achieved / 1e12:.1f}"
                         f"|peak_frac={frac:.2f}|regime={regime}"))
    # headline claims
    rows.append(("fig7/claims", 0,
                 "small_models_io_bound+superscalar_domain_loading"
                 "|large_models_compute_bound"))
    return rows


if __name__ == "__main__":
    emit(run())
