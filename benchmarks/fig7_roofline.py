"""Paper Fig. 7: roofline — I/O-bandwidth-limited vs computation-
communication-limited regimes for 1-, 2-, 4-way Jigsaw across the Table-1
zoo.

TPU adaptation: the paper measures achieved FLOP/s on A100s; we derive
the same two-regime structure analytically for the v5e target from our
FLOPs model + the domain-parallel I/O model (data/weather.py):

  t_io(n)      = sample_bytes / (n * DISK_BW)   (domain parallelism
                                                 divides I/O by n -- §5)
  t_compute    = flops_fwd_bwd / (n * PEAK)
  t_collective = jigsaw comm volume / ICI_BW

achieved FLOP/s = total_flops / max(t_io, t_compute + t_collective) / n.
The paper's claims checked here: (1) small models are I/O-bound and
parallel models get *superscalar* throughput from partitioned loading;
(2) at large model size the 2-way model stays near the 1-way compute
roofline (overlapped communication); (3) peak fractions.

ISSUE 2 extension: ``/chunked`` rows model the ``impl="ring_chunked"``
schedule, in which only the FIRST output-chunk's GEMM (1/p of the
compute) serializes before the ring and the remaining p-1 chunk GEMMs
overlap the p-1 hops:

  t_serial  = t_comp + t_coll                      (monolithic ring / rs)
  t_chunked = t_comp / p + max(t_comp * (p-1)/p, t_coll)

so a fully compute-bound layer hides its collective entirely -- the
paper's "each hop's send overlaps the next chunk's compute".  Chunked
rows appear only for the 2-way (1-D ring) case: the 4-way rows model
scheme="2d" Cannon, which has no ring_chunked variant in code (its
overlap is inherent to the skew/rotate schedule).

ISSUE 6 extension: ``/fused`` rows model ``impl="ring_fused"`` -- the
single-Pallas-kernel ring whose hops are in-kernel RDMA.  Its schedule
is the SAME formula as ``/chunked`` (same bytes, same chunk GEMMs),
which is the point: ring_fused does not change the roofline, it changes
who enforces it.  ``/chunked`` reaches the bound only if XLA's async
scheduler actually overlaps each ppermute with the next chunk GEMM
(best-effort, fragile across XLA versions); ``/fused`` reaches it by
construction, because the GEMM issues while the DMA is in flight inside
one kernel.  Rows are tagged ``overlap=xla-best-effort`` vs
``overlap=in-kernel`` to keep that distinction in the recorded table.
"""
from benchmarks.common import emit

DISK_BW = 2e9          # bytes/s per host stream (HoreKa-like Lustre share)
SAMPLE_BYTES = 4 * 721 * 1440 * 69   # one 0.25-deg f32 sample (paper)


def run():
    from repro.configs.weathermixer_1b import ZOO
    from repro.core.jigsaw import (comm_volume_jigsaw_1d,
                                   comm_volume_jigsaw_2d)
    from repro.launch import analysis as A

    rows = []
    for num, cfg in sorted(ZOO.items()):
        flops_fwd = sum(A.flops_forward(cfg, 1, 0).values())
        flops = 3 * flops_fwd                     # fwd + bwd
        t_tokens = (cfg.wm_lat // cfg.wm_patch) * (cfg.wm_lon // cfg.wm_patch)
        for way in (1, 2, 4):
            t_io = SAMPLE_BYTES / (way * DISK_BW)
            t_comp = flops / (way * A.PEAK_FLOPS_BF16)
            if way == 1:
                t_coll, p_ring = 0.0, 1
            elif way == 2:
                # 1-D jigsaw on every linear: RS of each layer's outputs
                v = 3 * (comm_volume_jigsaw_1d(t_tokens, cfg.wm_d_ch, way)
                         .bytes_per_device * 2 * cfg.n_layers)
                t_coll, p_ring = v / A.ICI_BW, way
            else:
                v = 3 * (comm_volume_jigsaw_2d(t_tokens, cfg.wm_d_ch, 2)
                         .bytes_per_device * 2 * cfg.n_layers)
                t_coll, p_ring = v / A.ICI_BW, 2
            scheds = [("", t_comp + t_coll, "none")]
            if way == 2:
                # chunked/fused ring (1-D only): 1/p of the compute
                # serializes, the rest overlaps the hops (see module
                # docstring).  Same formula for both -- fused differs in
                # WHO enforces the overlap, not in the bound itself.
                t_overlap = t_comp / p_ring + max(
                    t_comp * (p_ring - 1) / p_ring, t_coll)
                scheds.append(("/chunked", t_overlap, "xla-best-effort"))
                scheds.append(("/fused", t_overlap, "in-kernel"))
            for tag, t_cc, guar in scheds:
                t_step = max(t_io, t_cc)
                achieved = flops / t_step / way
                frac = achieved / A.PEAK_FLOPS_BF16
                regime = "io" if t_io > t_cc else "compute-comm"
                extra = "" if guar == "none" else f"|overlap={guar}"
                rows.append((f"fig7/model{num}/{way}way{tag}",
                             int(t_step * 1e6),
                             f"tflops_per_dev={achieved / 1e12:.1f}"
                             f"|peak_frac={frac:.2f}|regime={regime}"
                             f"{extra}"))
    # headline claims
    rows.append(("fig7/claims", 0,
                 "small_models_io_bound+superscalar_domain_loading"
                 "|large_models_compute_bound"
                 "|chunked_ring_hides_collectives_when_compute_bound"
                 "|fused_ring_enforces_that_overlap_in_kernel"))
    return rows


if __name__ == "__main__":
    emit(run())
