"""Checkpoint I/O benchmark (ISSUE 4): sync vs async sharded saves.

Measures, on a host-emulated (model=4, data=2) mesh running reduced-WM
training through ``TrainEngine``:

  * ``sync``   -- blocking sharded save on the train loop (snapshot +
                  write serialized with compute);
  * ``async``  -- the background writer: snapshot on the loop thread,
                  file writes overlapped with the next train steps;
  * per-rank bytes written (the zero-redundancy accounting: ~=
                  total_bytes / n_ranks, never a full-model gather).

Absolute numbers on CPU are artifacts; the contribution is the ratio
(steps+save)_async / (steps+save)_sync < 1 and the byte accounting.
Writes results/ckpt_io.csv unless --tiny (CI smoke).

``--preempt`` (ISSUE 7): measures the OTHER latency that matters for
fault tolerance -- how long a SIGTERM'd process takes to produce a
durable checkpoint (the final synchronous save of the preemption
choreography, DESIGN.md §12).  The row is APPENDED to the csv so the
sync/async rows need not be re-measured.
"""
import argparse
import os
import sys

if __package__ in (None, ""):   # `python benchmarks/ckpt_io.py`
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit, run_subprocess_devices

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "ckpt_io.csv")

MEASURE_CODE = """
import os, shutil, tempfile, time
import jax
from repro.configs.registry import get_config
from repro.launch.engine import EngineConfig, TrainEngine

cfg = get_config("weathermixer-1b").reduced().replace(
    scheme="1d", wm_lat={lat}, wm_lon={lon}, d_model={dm},
    wm_d_tok={dtok}, wm_d_ch={dch})
eng = TrainEngine("weathermixer-1b", reduced=False, config_override=cfg,
                  mesh_model=4, mesh_data=2, scheme="1d",
                  config=EngineConfig(steps=64, batch=4, zero1=True))
root = tempfile.mkdtemp()
steps_per_save = {steps_per_save}
reps = {reps}

def run(block, tag):
    # warmup: one compile + one save
    it = eng.pipeline.iterate([1] * (reps * steps_per_save + 1),
                              start_step=0)
    eng.dispatch(next(it), 1)
    eng.save(os.path.join(root, "warm"), block=True)
    jax.block_until_ready(jax.tree.leaves(eng.params)[0])
    t0 = time.time()
    for r in range(reps):
        eng.save(os.path.join(root, f"{{tag}}-{{r}}"), block=block)
        for _ in range(steps_per_save):
            eng.dispatch(next(it), 1)
        eng.wait_checkpoints()
    jax.block_until_ready(jax.tree.leaves(eng.params)[0])
    return (time.time() - t0) / reps

sync = run(True, "s")
async_ = run(False, "a")
per = eng.last_save.bytes_per_rank
print("SYNC", sync)
print("ASYNC", async_)
print("MAXRANKBYTES", max(per.values()))
print("TOTALBYTES", eng.last_save.total_bytes)
print("NRANKS", eng.mesh.devices.size)
"""


PREEMPT_CODE = """
import os, tempfile
from repro.configs.registry import get_config
from repro.checkpoint import sharded
from repro.launch import resilience
from repro.launch.engine import EngineConfig, TrainEngine

cfg = get_config("weathermixer-1b").reduced().replace(
    scheme="1d", wm_lat={lat}, wm_lon={lon}, d_model={dm},
    wm_d_tok={dtok}, wm_d_ch={dch})
root = tempfile.mkdtemp()
eng = TrainEngine("weathermixer-1b", reduced=False, config_override=cfg,
                  mesh_model=4, mesh_data=2, scheme="1d",
                  config=EngineConfig(steps=16, batch=4, zero1=True,
                                      log_every=100,
                                      ckpt=os.path.join(root, "ck"),
                                      preempt_at_step=2))
try:
    eng.run()
    raise SystemExit("expected a Preempted exit")
except resilience.Preempted as p:
    assert sharded.checkpoint_complete(p.checkpoint), p.checkpoint
    print("FINALSAVES", eng.preempt_stats["final_save_s"])
    print("TOTALBYTES", eng.last_save.total_bytes)
    print("MAXRANKBYTES", max(eng.last_save.bytes_per_rank.values()))
"""


def run_preempt(tiny: bool = False):
    lat, lon, dm, dtok, dch = ((32, 64, 64, 64, 64) if tiny
                               else (96, 192, 256, 512, 512))
    out = run_subprocess_devices(
        PREEMPT_CODE.format(lat=lat, lon=lon, dm=dm, dtok=dtok, dch=dch),
        n_devices=8)
    vals = {l.split()[0]: float(l.split()[1])
            for l in out.splitlines() if l and l.split()[0].isupper()}
    total, maxr = int(vals["TOTALBYTES"]), int(vals["MAXRANKBYTES"])
    return [
        ("ckpt/preempt_final_save", int(vals["FINALSAVES"] * 1e6),
         f"sigterm_to_durable|bytes={total}|max_rank={maxr}"),
    ]


def run(tiny: bool = False):
    lat, lon, dm, dtok, dch = ((32, 64, 64, 64, 64) if tiny
                               else (96, 192, 256, 512, 512))
    out = run_subprocess_devices(
        MEASURE_CODE.format(lat=lat, lon=lon, dm=dm, dtok=dtok, dch=dch,
                            steps_per_save=2 if tiny else 4,
                            reps=2 if tiny else 5),
        n_devices=8)
    vals = {l.split()[0]: float(l.split()[1])
            for l in out.splitlines() if l and l.split()[0].isupper()}
    sync, async_ = vals["SYNC"], vals["ASYNC"]
    total, maxr = int(vals["TOTALBYTES"]), int(vals["MAXRANKBYTES"])
    n = int(vals["NRANKS"])
    return [
        ("ckpt/sync_save+steps", int(sync * 1e6), "blocking write"),
        ("ckpt/async_save+steps", int(async_ * 1e6),
         f"overlap_speedup={sync / async_:.2f}x"),
        ("ckpt/bytes_per_rank", maxr,
         f"total={total}|ranks={n}|ideal={total // n}"
         f"|ratio={maxr * n / total:.2f}"),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: small grid, no results/ write")
    ap.add_argument("--no-write", action="store_true")
    ap.add_argument("--preempt", action="store_true",
                    help="measure only the SIGTERM->durable final-save "
                         "latency; the row is appended to the csv")
    ap.add_argument("--out", default=RESULTS)
    args = ap.parse_args()
    rows = run_preempt(tiny=args.tiny) if args.preempt else run(tiny=args.tiny)
    emit(rows)
    if not args.tiny and not args.no_write:
        mode = "a" if args.preempt else "w"
        header = not (args.preempt and os.path.exists(args.out))
        with open(args.out, mode) as f:
            if header:
                f.write("name,us_per_call,derived\n")
            for r in rows:
                f.write(",".join(str(x) for x in r) + "\n")
        print(f"[ckpt_io] wrote {args.out}")


if __name__ == "__main__":
    main()
