"""Paper Fig. 10 / Table 2: weak scaling of intra-node MP x inter-node DP
to 256 GPUs.

Analytic v5e model: per-step time = compute + jigsaw-MP collectives +
DP gradient allreduce (ring over the data axis; volume = local param
shard bytes -- the paper's point: MP shards the model, so each DP ring
only reduces 1/n of the parameters, which is why 2-/4-way scale better
than 1-way at 256 devices: 68%/72% vs 51%).

Plus a MEASURED weak-scaling row set: a real reduced-WM step through
``TrainEngine`` (sharded input pipeline included) at dp = 1/2/4 on
host-emulated devices with constant per-device batch.  Absolute times
are CPU artifacts; the ratios expose the DP gradient-reduction cost.
"""
from benchmarks.common import emit, run_subprocess_devices

# thin TrainEngine caller, mirroring fig89's strong-scaling probe
MEASURE_CODE = """
from repro.configs.registry import get_config
from repro.launch.engine import EngineConfig, TrainEngine

dp = {dp}
cfg = get_config("weathermixer-1b").reduced().replace(
    scheme="1d" if dp > 1 else "none",
    wm_lat=32, wm_lon=64, d_model=128, wm_d_tok=256, wm_d_ch=128)
eng = TrainEngine("weathermixer-1b", reduced=False, config_override=cfg,
                  mesh_model=1, mesh_data=dp, scheme=cfg.scheme,
                  config=EngineConfig(steps=12, batch=4 * dp))
print("SECONDS", eng.benchmark(steps=10, warmup=2))
"""


def measured_dp_scaling():
    rows = []
    t1 = None
    for dp in (1, 2, 4):
        out = run_subprocess_devices(MEASURE_CODE.format(dp=dp),
                                     n_devices=max(dp, 1))
        secs = float([l for l in out.splitlines()
                      if l.startswith("SECONDS")][0].split()[1])
        t1 = t1 or secs
        rows.append((f"fig10/measured/{dp}dp", int(secs * 1e6),
                     f"weak_eff={t1 / secs:.2f}"))
    return rows


def table2_configs():
    # (ways, TFLOPs/fwd/GPU, params_mil) -- paper Table 2
    return [(1, 16, 1000), (2, 32, 1400), (4, 64, 2400)]


def run():
    from repro.configs.weathermixer_1b import ZOO, _wm
    from repro.core.jigsaw import comm_volume_jigsaw_1d
    from repro.launch import analysis as A

    cfg_for = {1: ZOO[7], 2: ZOO[8], 9: ZOO[9], 4: ZOO[9]}
    rows = []
    for ways, tf, params_mil in table2_configs():
        cfg = cfg_for[ways]
        flops = 3 * sum(A.flops_forward(cfg, 1, 0).values())
        t_tokens = (cfg.wm_lat // cfg.wm_patch) * (cfg.wm_lon // cfg.wm_patch)
        params_bytes = cfg.param_count() * 4
        base_t = None
        for gpus in (ways, 8, 64, 256):
            dp = gpus // ways
            t_comp = flops / (ways * A.PEAK_FLOPS_BF16)
            v_mp = 0 if ways == 1 else 3 * 2 * cfg.n_layers * \
                comm_volume_jigsaw_1d(t_tokens, cfg.d_model,
                                      ways).bytes_per_device
            # DP ring allreduce of the LOCAL param shard
            shard = params_bytes / ways
            v_dp = 0 if dp == 1 else 2 * (dp - 1) / dp * shard
            t = t_comp + (v_mp + v_dp) / A.ICI_BW
            base_t = base_t or t
            eff = base_t / t
            pflops = flops * dp / t / 1e15
            rows.append((f"fig10/{ways}way/{gpus}gpu", int(t * 1e6),
                         f"weak_eff={eff:.2f}|agg_pflops={pflops:.1f}"))
    rows.append(("fig10/claim", 0,
                 "MP_shards_gradients=>higher_DP_efficiency_at_256"))
    rows += measured_dp_scaling()
    return rows


if __name__ == "__main__":
    emit(run())
