"""Forecast serving throughput benchmark (ISSUE 8).

Trains a tiny WeatherMixer on a host-emulated (model=4, data=2) mesh,
saves a sharded checkpoint, then serves it with ``ForecastEngine`` on an
8-way data-only serving mesh (the restore-anywhere path:
checkpoint/serving.py refits the 8-way specs onto the serving mesh).

Measured:

  * ``continuous`` vs ``drain`` requests/s at mixed lead times
    (1 and 8 rollout steps, alternating).  Drain pays max(lead) device
    steps for every batch; continuous refills freed slots at step
    boundaries and pays ~mean(lead).  ASSERTS continuous >= 1.2x drain
    (also in --tiny: the gap is structural, not a timing artifact);
  * zero steady-state recompiles: after ``warmup()`` a serving session
    crossing four batch buckets (8 -> re-form at 1 -> grow 2 -> grow 4)
    performs ZERO new traces (trace-time compile counter) --
    ASSERTED, also in --tiny;
  * serving-precision rows: the same fp32 checkpoint served bf16
    (weights cast on restore) vs fp32;
  * serving-mesh rows: mesh_data=1 vs mesh_data=8 for the same load.

Absolute numbers on CPU are artifacts (results/README.md); the
contributions are the continuous/drain ratio and the zero-recompile
steady state.  Writes results/serve_throughput.csv unless --tiny.
"""
import argparse
import os
import sys

if __package__ in (None, ""):   # `python benchmarks/serve_throughput.py`
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit, run_subprocess_devices

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "serve_throughput.csv")

MEASURE_CODE = """
import os, tempfile, time
import numpy as np
from repro.configs.registry import get_config
from repro.launch.engine import EngineConfig, TrainEngine
from repro.serve.engine import ForecastEngine, ServeConfig

cfg = get_config("weathermixer-1b").reduced().replace(
    scheme="1d", wm_lat={lat}, wm_lon={lon}, d_model={dm},
    wm_d_tok={dtok}, wm_d_ch={dch})
R = {requests}
LEADS = [1, 8]        # mixed horizons: the continuous-vs-drain gap

# -- an 8-way (model=4, data=2) training checkpoint to serve ------------
ckpt = os.path.join(tempfile.mkdtemp(), "ck")
trainer = TrainEngine("weathermixer-1b", reduced=False,
                      config_override=cfg, mesh_model=4, mesh_data=2,
                      scheme="1d",
                      config=EngineConfig(steps=2, batch=4, log_every=10))
trainer.run()
trainer.save(ckpt, block=True)

rng = np.random.default_rng(0)
fields = rng.normal(size=(R, cfg.wm_lat, cfg.wm_lon,
                          cfg.wm_channels)).astype(np.float32)

def build(mode="continuous", mesh_data=8, precision=None):
    eng = ForecastEngine("weathermixer-1b", reduced=False,
                         config_override=cfg, ckpt=ckpt,
                         mesh_data=mesh_data,
                         config=ServeConfig(buckets=(1, 2, 4, 8),
                                            mode=mode,
                                            precision=precision))
    eng.warmup()
    return eng

def load(eng):
    t0 = time.perf_counter()
    rs = [eng.submit(fields[i], LEADS[i % len(LEADS)]) for i in range(R)]
    eng.drain()
    wall = time.perf_counter() - t0
    assert all(r.done() for r in rs)
    return rs, wall

cont = build("continuous")
rs, wall_c = load(cont)
s = cont.summary(rs)

# -- zero-recompile steady state across >=3 buckets ---------------------
# the big load ran at bucket 8; now traverse 1 -> grow 2 -> grow 4
cont.submit(fields[0], 4)
assert cont.step_once() == "step"
for i in (1, 2, 3):
    cont.submit(fields[i], 2)
cont.drain()
sc = cont.sched.counters
assert sc["formed"] >= 2 and sc["grown"] >= 2, sc
delta = cont.stats["compiles"] - cont.stats["warm_compiles"]
assert delta == 0, f"{{delta}} steady-state recompiles"
cache = cont.compile_cache_size()
assert cache in (-1, cont.stats["compiles"]), (
    f"jit cache {{cache}} != traces {{cont.stats['compiles']}}")

drain = build("drain")
rd, wall_d = load(drain)
ratio = (R / wall_c) / (R / wall_d)
assert ratio >= 1.2, f"continuous only {{ratio:.2f}}x drain"

print("CONTWALL", wall_c)
print("DRAINWALL", wall_d)
print("CONTSTEPS", s["device_steps"])
print("DRAINSTEPS", drain.stats["device_steps"])
print("P50", s["p50_s"])
print("P95", s["p95_s"])
print("WARMCOMPILES", cont.stats["warm_compiles"])
print("RECOMPILES", delta)
print("FORMED", sc["formed"])
print("GROWN", sc["grown"])

_, wall_b = load(build(precision="bf16"))
print("BF16WALL", wall_b)
_, wall_1 = load(build(mesh_data=1))
print("MESH1WALL", wall_1)
"""


def run(tiny: bool = False):
    lat, lon, dm, dtok, dch = ((16, 32, 64, 64, 64) if tiny
                               else (48, 96, 128, 192, 192))
    requests = 16 if tiny else 48
    out = run_subprocess_devices(
        MEASURE_CODE.format(lat=lat, lon=lon, dm=dm, dtok=dtok, dch=dch,
                            requests=requests),
        n_devices=8)
    vals = {l.split()[0]: float(l.split()[1])
            for l in out.splitlines() if l and l.split()[0].isupper()}
    wc, wd = vals["CONTWALL"], vals["DRAINWALL"]
    rps = lambda w: requests / w
    return [
        ("serve/continuous", int(wc / requests * 1e6),
         f"req_s={rps(wc):.1f}|vs_drain={rps(wc) / rps(wd):.2f}x"
         f"|steps={int(vals['CONTSTEPS'])}"),
        ("serve/drain", int(wd / requests * 1e6),
         f"req_s={rps(wd):.1f}|steps={int(vals['DRAINSTEPS'])}"),
        ("serve/latency", int(vals["P50"] * 1e6),
         f"p95_us={int(vals['P95'] * 1e6)}|mixed_leads=1,8"),
        ("serve/steady_state_recompiles", int(vals["RECOMPILES"]),
         f"warm={int(vals['WARMCOMPILES'])}|buckets=1,2,4,8"
         f"|formed={int(vals['FORMED'])}|grown={int(vals['GROWN'])}"),
        ("serve/bf16", int(vals["BF16WALL"] / requests * 1e6),
         f"vs_fp32={wc / vals['BF16WALL']:.2f}x|cast_on_restore"),
        ("serve/mesh_data1", int(vals["MESH1WALL"] / requests * 1e6),
         f"vs_8way={vals['MESH1WALL'] / wc:.2f}x_slower"),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: small grid, no results/ write "
                         "(assertions stay on)")
    ap.add_argument("--no-write", action="store_true")
    ap.add_argument("--out", default=RESULTS)
    args = ap.parse_args()
    rows = run(tiny=args.tiny)
    emit(rows)
    if not args.tiny and not args.no_write:
        with open(args.out, "w") as f:
            f.write("name,us_per_call,derived\n")
            for r in rows:
                f.write(",".join(str(x) for x in r) + "\n")
        print(f"[serve_throughput] wrote {args.out}")


if __name__ == "__main__":
    main()
