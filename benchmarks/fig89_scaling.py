"""Paper Figs. 8 (strong) and 9 (weak) scaling of Jigsaw model parallelism.

Two complementary measurements:
  * MEASURED: wall-clock of a real reduced-WM train step at 1-, 2-, 4-way
    Jigsaw on host-emulated devices (subprocess per mesh size).  Absolute
    times are CPU-emulation artifacts, but the ratios expose the
    communication structure.
  * ANALYTIC (v5e): roofline-model speedups for the paper's model sizes
    (1/4/16 TFLOPs per forward pass), with and without data loading --
    the four panels of Fig. 8, plus the Fig. 9 weak-scaling efficiency.

Paper baselines to beat: Megatron-LM strong scaling 1.6x/2.3x (2/4-way)
on a 1.2B model; weak scaling 82%.
"""
from benchmarks.common import emit, run_subprocess_devices

# thin TrainEngine caller: the engine owns mesh, pipeline, and step
# dispatch; the benchmark only picks the model-parallel degree.
MEASURE_CODE = """
from repro.configs.registry import get_config
from repro.launch.engine import EngineConfig, TrainEngine

way = {way}
cfg = get_config("weathermixer-1b").reduced().replace(
    scheme="1d" if way > 1 else "none",
    wm_lat=64, wm_lon=128, d_model=256, wm_d_tok=512, wm_d_ch=256)
eng = TrainEngine("weathermixer-1b", reduced=False, config_override=cfg,
                  mesh_model=way, mesh_data=1, scheme=cfg.scheme,
                  config=EngineConfig(steps=12, batch=4))
print("SECONDS", eng.benchmark(steps=10, warmup=2))
"""


def measured_strong_scaling():
    rows = []
    times = {}
    for way in (1, 2, 4):
        out = run_subprocess_devices(MEASURE_CODE.format(way=way),
                                     n_devices=max(way, 1))
        secs = float([l for l in out.splitlines()
                      if l.startswith("SECONDS")][0].split()[1])
        times[way] = secs
        rows.append((f"fig8/measured/{way}way", int(secs * 1e6),
                     f"speedup={times[1] / secs:.2f}"))
    return rows, times


def analytic_scaling():
    """v5e roofline model for the paper's 1/4/16-TFLOP models."""
    from repro.configs.weathermixer_1b import ZOO
    from repro.core.jigsaw import comm_volume_jigsaw_1d
    from repro.launch import analysis as A
    from benchmarks.fig7_roofline import DISK_BW, SAMPLE_BYTES

    rows = []
    for num, label in [(3, "1T"), (5, "4T"), (7, "16T")]:
        cfg = ZOO[num]
        flops = 3 * sum(A.flops_forward(cfg, 1, 0).values())
        t_tokens = (cfg.wm_lat // cfg.wm_patch) * (cfg.wm_lon // cfg.wm_patch)
        for with_io in (False, True):
            t1 = None
            for way in (1, 2, 4):
                t_comp = flops / (way * A.PEAK_FLOPS_BF16)
                v = 0 if way == 1 else 3 * 2 * cfg.n_layers * \
                    comm_volume_jigsaw_1d(t_tokens, cfg.d_model,
                                          way).bytes_per_device
                t_coll = v / A.ICI_BW
                t_io = SAMPLE_BYTES / (way * DISK_BW) if with_io else 0.0
                t = max(t_io, t_comp + t_coll)
                t1 = t1 or t
                rows.append((
                    f"fig8/analytic/{label}/{'full' if with_io else 'noio'}"
                    f"/{way}way", int(t * 1e6),
                    f"speedup={t1 / t:.2f}"))
    # Fig 9 weak scaling: FLOPs/GPU constant (models 3,5,7 at 1,2,4-way)
    for with_io in (False, True):
        base_t = None
        for way, num in [(1, 3), (2, 5), (4, 7)]:
            cfg = ZOO[num]
            flops = 3 * sum(A.flops_forward(cfg, 1, 0).values())
            t_tokens = (cfg.wm_lat // cfg.wm_patch) * \
                (cfg.wm_lon // cfg.wm_patch)
            t_comp = flops / (way * A.PEAK_FLOPS_BF16)
            v = 0 if way == 1 else 3 * 2 * cfg.n_layers * \
                comm_volume_jigsaw_1d(t_tokens, cfg.d_model,
                                      way).bytes_per_device
            t_io = SAMPLE_BYTES / (way * DISK_BW) if with_io else 0.0
            t = max(t_io, t_comp + v / A.ICI_BW)
            base_t = base_t or t
            eff = base_t / t
            rows.append((f"fig9/analytic/{'full' if with_io else 'noio'}"
                         f"/{way}way", int(t * 1e6),
                         f"weak_eff={eff:.2f}"
                         f"|superscalar={eff > 1.001}"))
    return rows


def run():
    rows, _ = measured_strong_scaling()
    rows += analytic_scaling()
    return rows


if __name__ == "__main__":
    emit(run())
