"""A/B benchmark: Pallas fused GEMMs vs XLA, serial vs chunked ring.

Three tables (``name,us_per_call,derived`` rows like every benchmark):

  kf/gemm/<shape>/{xla,pallas}       y = gelu(x @ w.T + b), one device
  kf/mlp/<shape>/{xla,pallas}        the mixer MLP: unfused vs fused
                                     two-GEMM (ops.mixer_mlp)
  kf/ring/<impl>[/pallas]            jigsaw_linear on an 8-way host mesh:
                                     rs vs ring vs ring_chunked
  kf/roofline/ring*                  analytic per-hop overlap accounting
                                     (comm_schedule_jigsaw_1d) at v5e BW

On CPU the pallas rows run in INTERPRET mode: they track the code path
for regressions, not performance (the fig7 roofline model carries the
analytic perf claims; on a real TPU the same script measures compiled
kernels).  The backend is recorded in every derived field.

Writes the table to results/kernel_fusion.csv unless --tiny (CI smoke)
or --no-write is given.
"""
import argparse
import os
import sys
import time

if __package__ in (None, ""):   # `python benchmarks/kernel_fusion.py`
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit, run_subprocess_devices

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "kernel_fusion.csv")

RING_CODE = """
import time, jax, jax.numpy as jnp
from repro.core.api import JigsawConfig, linear_apply, linear_init
from repro.launch.mesh import make_host_mesh

B, T, D, M, ITERS = {b}, {t}, {d}, {m}, {iters}
mesh = make_host_mesh(model=8, data=1)
params = linear_init(jax.random.PRNGKey(0), D, M)
x = jax.random.normal(jax.random.PRNGKey(1), (B, T, D))
with jax.set_mesh(mesh):
    for impl, kern in [("rs", "xla"), ("ring", "xla"),
                       ("ring_chunked", "xla"),
                       ("ring_chunked", "pallas"),
                       ("ring_fused", "xla"),
                       ("ring_fused", "pallas")]:
        if kern == "pallas" and not {with_pallas}:
            continue
        cfg = JigsawConfig(impl=impl, kernel=kern)
        fn = jax.jit(lambda p, v: linear_apply(p, v, cfg))
        fn(params, x).block_until_ready()
        t0 = time.time()
        for _ in range(ITERS):
            fn(params, x).block_until_ready()
        us = (time.time() - t0) / ITERS * 1e6
        print(f"RING {{impl}} {{kern}} {{us:.0f}}")
"""


def _timed(fn, *args, iters=5):
    import jax
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters


def run(tiny: bool = False):
    import jax
    import jax.numpy as jnp
    from repro.core.api import JigsawConfig, mlp_apply, mlp_init
    from repro.core.jigsaw import comm_schedule_jigsaw_1d
    from repro.kernels import ops
    from repro.launch import analysis as A

    backend = jax.default_backend()
    mode = "compiled" if backend == "tpu" else "cpu-interpret"
    iters = 2 if tiny else 5
    rows = []

    # --- single-GEMM A/B: bias + GELU epilogue ------------------------
    shapes = [(128, 128, 256)] if tiny else [(256, 512, 1024),
                                             (512, 512, 2048)]
    for m, k, n in shapes:
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
        x = jax.random.normal(k1, (m, k))
        w = jax.random.normal(k2, (n, k)) * 0.05
        b = jax.random.normal(k3, (n,)) * 0.1
        flops = 2.0 * m * k * n

        def xla_gemm(x, w, b):
            return jax.nn.gelu(
                jax.lax.dot_general(x, w, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
                + b[None, :]).astype(x.dtype)

        t_x = _timed(jax.jit(xla_gemm), x, w, b, iters=iters)
        t_p = _timed(lambda x, w, b: ops.matmul(x, w, b, epilogue="gelu"),
                     x, w, b, iters=iters)
        for name, t in (("xla", t_x), ("pallas", t_p)):
            rows.append((f"kf/gemm/{m}x{k}x{n}/{name}", int(t * 1e6),
                         f"gflops={flops / t / 1e9:.1f}|mode={mode}"))

    # --- mixer MLP A/B: unfused vs fused two-GEMM ---------------------
    mshapes = [(64, 128, 128)] if tiny else [(256, 512, 1024)]
    for rows_m, d_in, d_h in mshapes:
        params = mlp_init(jax.random.PRNGKey(1), d_in, d_h, d_in)
        x = jax.random.normal(jax.random.PRNGKey(2), (2, rows_m, d_in))
        flops = 2.0 * 2 * rows_m * d_in * d_h * 2
        for name, cfg in (("xla", JigsawConfig(scheme="none")),
                          ("pallas", JigsawConfig(scheme="none",
                                                  kernel="pallas"))):
            t = _timed(jax.jit(lambda p, v, c=cfg: mlp_apply(p, v, c)),
                       params, x, iters=iters)
            rows.append((f"kf/mlp/{rows_m}x{d_in}x{d_h}/{name}",
                         int(t * 1e6),
                         f"gflops={flops / t / 1e9:.1f}|mode={mode}"))

    # --- ring schedules on an 8-way host mesh (subprocess) ------------
    b_, t_, d_, m_ = (2, 32, 128, 128) if tiny else (4, 256, 512, 512)
    out = run_subprocess_devices(
        RING_CODE.format(b=b_, t=t_, d=d_, m=m_, iters=iters,
                         with_pallas=not tiny), 8)
    for line in out.splitlines():
        if line.startswith("RING"):
            _, impl, kern, us = line.split()
            tag = f"kf/ring/{impl}" + ("" if kern == "xla" else f"/{kern}")
            rows.append((tag, int(float(us)),
                         f"shape={b_}x{t_}x{d_}x{m_}|mode={mode}"))

    # --- analytic per-hop overlap (the fused ring's point) ------------
    # ring: zero overlappable work; ring_chunked: one chunk GEMM exposed
    # per hop, but GEMM and hop are separate HLOs (XLA-best-effort);
    # ring_fused: the same chunk GEMM + the hop add executed INSIDE the
    # kernel while the RDMA flies -- guaranteed overlap.  The fused rows
    # are the schedule the TPU kernel enforces; on this CPU host they are
    # analytic only (see results/ caveat).
    tokens, m, d, p = 4096, 4320, 4320, 8
    for impl in ("ring", "ring_chunked", "ring_fused"):
        cs = comm_schedule_jigsaw_1d(tokens, m, d // p, p, impl=impl)
        ratio = cs.overlap_ratio(A.ICI_BW, A.PEAK_FLOPS_BF16)
        guar = "in-kernel" if impl == "ring_fused" else \
            ("xla-best-effort" if impl == "ring_chunked" else "none")
        rows.append((f"kf/roofline/{cs.scheme}", 0,
                     f"hops={cs.hops}|bytes_per_hop={cs.bytes_per_hop:.0f}"
                     f"|flops_per_hop={cs.flops_per_hop:.2e}"
                     f"|overlap_ratio={ratio:.2f}|overlap={guar}"))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: small shapes, no results/ write")
    ap.add_argument("--no-write", action="store_true")
    ap.add_argument("--out", default=RESULTS)
    args = ap.parse_args()
    rows = run(tiny=args.tiny)
    emit(rows)
    if not args.tiny and not args.no_write:
        with open(args.out, "w") as f:
            f.write("name,us_per_call,derived\n")
            for r in rows:
                f.write(",".join(str(x) for x in r) + "\n")
        print(f"[kernel_fusion] wrote {args.out}")


if __name__ == "__main__":
    main()
