"""Benchmark harness entry point: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig7,fig10] [--fast]

Prints ``name,us_per_call,derived`` CSV (plus section banners on stderr).
"""
import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module keywords")
    ap.add_argument("--fast", action="store_true",
                    help="skip the training-based benchmarks")
    args = ap.parse_args()

    from benchmarks import (comm_volume, fig3_scaling_loss,
                            fig4_equivalent_usage, fig7_roofline,
                            fig10_dp_scaling, fig56_rollout, fig89_scaling,
                            pipeline_overlap, table1_model_zoo,
                            table3_energy)

    modules = [
        ("table1", table1_model_zoo),
        ("fig3", fig3_scaling_loss),
        ("fig4", fig4_equivalent_usage),
        ("fig56", fig56_rollout),
        ("fig7", fig7_roofline),
        ("fig89", fig89_scaling),
        ("fig10", fig10_dp_scaling),
        ("pipeline", pipeline_overlap),
        ("table3", table3_energy),
        ("comm", comm_volume),
    ]
    slow = {"fig3", "fig4", "fig56", "fig89", "fig10", "pipeline"}
    if args.fast:
        modules = [(k, m) for k, m in modules if k not in slow]
    if args.only:
        keys = set(args.only.split(","))
        modules = [(k, m) for k, m in modules if k in keys]

    print("name,us_per_call,derived")
    failures = []
    for key, mod in modules:
        print(f"[bench] {key} ({mod.__name__})", file=sys.stderr)
        t0 = time.time()
        try:
            rows = mod.run()
            for r in rows:
                print(",".join(str(x) for x in r))
        except Exception as e:
            failures.append((key, e))
            traceback.print_exc()
            print(f"{key}/ERROR,0,{type(e).__name__}")
        print(f"[bench] {key} done in {time.time() - t0:.1f}s",
              file=sys.stderr)
    if failures:
        raise SystemExit(f"{len(failures)} benchmark(s) failed: "
                         f"{[k for k, _ in failures]}")


if __name__ == "__main__":
    main()
