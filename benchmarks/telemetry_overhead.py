"""Telemetry overhead benchmark (ISSUE 9): the span/record
instrumentation must cost < 2% of step time.

One ``TrainEngine`` runs alternating K-step blocks with the tracer
enabled and disabled (toggled between blocks, so compile state, input
pipeline, host thermal drift and jit caches are IDENTICAL across the
two populations -- the only difference is whether ``span()`` allocates
and buffers events).  Per-step wall times come from ``on_step``
timestamp deltas; the first block is warmup and every block drops its
first step (the toggle boundary).  Overhead = (median_on - median_off)
/ median_off over the pooled blocks, asserted < 2%.

The per-call cost of the primitives themselves (span enter/exit,
counter, gauge, step_record) is also measured in a tight loop --
those are the numbers the <2% budget is built from (DESIGN.md §14).

Writes results/telemetry_overhead.csv unless --tiny (the CI smoke,
which still asserts the budget).
"""
import argparse
import os
import statistics
import sys
import time

if __package__ in (None, ""):   # `python benchmarks/telemetry_overhead.py`
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import SRC, emit  # noqa: F401  (SRC sets sys.path)

from repro.launch.engine import EngineConfig, TrainEngine  # noqa: E402
from repro.telemetry.spans import Tracer  # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "telemetry_overhead.csv")


def measure_engine(arch="internlm2-1.8b", *, block=16, blocks=6,
                   batch=2, seq_len=32):
    """Alternating enabled/disabled blocks on one engine; returns
    (on_s, off_s, n_on, n_off).

    Estimator: the per-block MINIMUM step time (host scheduling noise
    only ever adds time), differenced between ADJACENT on/off block
    pairs (slow drift -- a loaded CI host warming up or backing off --
    cancels within a pair), medianed across pairs."""
    steps = block * (blocks + 1)          # +1 warmup block
    eng = TrainEngine(arch, config=EngineConfig(
        steps=steps, batch=batch, seq_len=seq_len,
        log_every=10 ** 9, telemetry=True))
    per_block = {}                        # block index -> [step times]
    state = {"t": None}

    def on_step(i, metrics):
        now = time.perf_counter()
        prev, state["t"] = state["t"], now
        b = i // block
        if b == 0 or i % block == 0 or prev is None:
            # warmup block / toggle-boundary step: discard, then flip
            # the tracer for the block that starts here
            eng.tracer.enabled = (b % 2 == 1)
            return
        per_block.setdefault(b, []).append(now - prev)

    eng.run(on_step=on_step)
    mins = {b: min(ts) for b, ts in per_block.items()}
    # block 1 is on, 2 off, 3 on, ... -> pairs (1,2), (3,4), ...
    diffs, offs, n_on, n_off = [], [], 0, 0
    for b in sorted(mins):
        if b % 2 == 0:
            continue
        if b + 1 not in mins:
            break
        diffs.append(mins[b] - mins[b + 1])
        offs.append(mins[b + 1])
        n_on += len(per_block[b])
        n_off += len(per_block[b + 1])
    t_off = statistics.median(offs)
    t_on = t_off + statistics.median(diffs)
    return t_on, t_off, n_on, n_off


def measure_primitives(n=20000):
    """Tight-loop cost of each tracer primitive, in us/call."""
    tr = Tracer()
    out = {}
    t0 = time.perf_counter()
    for i in range(n):
        with tr.span("bench", i=i):
            pass
    out["span"] = (time.perf_counter() - t0) / n * 1e6
    t0 = time.perf_counter()
    for _ in range(n):
        tr.counter("c")
    out["counter"] = (time.perf_counter() - t0) / n * 1e6
    t0 = time.perf_counter()
    for i in range(n):
        tr.gauge("g", i)
    out["gauge"] = (time.perf_counter() - t0) / n * 1e6
    t0 = time.perf_counter()
    for i in range(n):
        tr.step_record(step=i, dur_s=0.1, mfu=0.5)
    out["step_record"] = (time.perf_counter() - t0) / n * 1e6
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: fewer blocks, no csv write "
                         "(the <2%% assertion still runs)")
    ap.add_argument("--budget", type=float, default=0.02,
                    help="max allowed relative step-time overhead")
    args = ap.parse_args()

    prim = measure_primitives(4000 if args.tiny else 20000)
    # enough samples per arm that host scheduling noise (which dwarfs
    # the ~15us of actual span work on a >10ms step) medians out
    block, blocks = (8, 14) if args.tiny else (16, 16)
    t_on, t_off, n_on, n_off = measure_engine(block=block, blocks=blocks)
    overhead = (t_on - t_off) / t_off

    rows = [("telemetry/step_overhead_pct", round(overhead * 100, 3),
             f"on_us={t_on * 1e6:.0f}|off_us={t_off * 1e6:.0f}"
             f"|steps={n_on}+{n_off}|budget={args.budget * 100:.0f}%")]
    for name, us in sorted(prim.items()):
        rows.append((f"telemetry/{name}", round(us, 3), "us_per_call"))
    emit(rows)

    if not args.tiny:
        with open(RESULTS, "w") as f:
            f.write("name,us_per_call,derived\n")
            for r in rows:
                f.write(",".join(str(x) for x in r) + "\n")
        print(f"wrote {os.path.relpath(RESULTS)}")

    assert overhead < args.budget, (
        f"telemetry overhead {overhead * 100:.2f}% exceeds the "
        f"{args.budget * 100:.0f}% budget "
        f"(on {t_on * 1e6:.0f}us vs off {t_off * 1e6:.0f}us per step)")
    print(f"OK: telemetry overhead {overhead * 100:+.2f}% "
          f"(budget {args.budget * 100:.0f}%)")


if __name__ == "__main__":
    main()
