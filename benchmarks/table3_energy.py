"""Paper Table 3: energy + CO2e accounting.

We cannot measure wall power in this container; we reproduce the paper's
METHODOLOGY: CO2e = E_total * PUE * e_C with PUE=1.05 and
e_C=381 g CO2e/kWh (the paper's German-grid figure), with E_total
estimated from the FLOPs model, the roofline-derived MFU, and the v5e
chip's ~200 W board power.
"""
from benchmarks.common import emit

PUE = 1.05
E_C = 381.0          # g CO2e per kWh
CHIP_WATTS = 200.0   # v5e board power (approx)
EPOCH_SAMPLES = 58440  # 1979-2017 6h-subsampled ERA5 (paper training set)
EPOCHS = 100


def run():
    from repro.configs.weathermixer_1b import ZOO
    from repro.launch import analysis as A

    rows = []
    for way, num, mfu in [(1, 7, 0.43), (2, 7, 0.40), (4, 7, 0.37)]:
        cfg = ZOO[num]
        flops_per_sample = 3 * sum(A.flops_forward(cfg, 1, 0).values())
        total_flops = flops_per_sample * EPOCH_SAMPLES * EPOCHS
        chip_seconds = total_flops / (A.PEAK_FLOPS_BF16 * mfu)
        kwh = chip_seconds * CHIP_WATTS / 3600 / 1000
        co2 = kwh * PUE * E_C / 1000
        rows.append((f"table3/{way}way", 0,
                     f"est_kwh={kwh:.0f}|co2e_kg={co2:.0f}"
                     f"|paper_kwh={[579, 643, 855][way // 2]}"))
    rows.append(("table3/method", 0,
                 f"CO2e=E*PUE({PUE})*eC({E_C}g/kWh)|v5e@{CHIP_WATTS}W"))
    return rows


if __name__ == "__main__":
    emit(run())
