"""Paper Fig. 3: validation loss of increasingly large WeatherMixers.

Claim: larger WM -> lower loss (neural scaling).  We train three reduced
WM sizes on the synthetic ERA5-like pipeline and compare *validation*
losses (held-out steps).
"""
import numpy as np

from benchmarks.common import Timer, emit


def run(steps: int = 60):
    import jax
    from repro.configs.registry import get_config
    from repro.launch.train import train
    from repro.launch import shapes as SH
    from repro.models import registry as M
    from repro.train.step import make_eval_step
    from repro.data.weather import WeatherDataConfig, WeatherDataset

    base = get_config("weathermixer-1b").reduced()
    sizes = {"small": dict(d_model=64, wm_d_tok=64, wm_d_ch=64),
             "medium": dict(d_model=128, wm_d_tok=128, wm_d_ch=128),
             "large": dict(d_model=256, wm_d_tok=384, wm_d_ch=256)}
    rows = []
    finals = {}
    for name, kw in sizes.items():
        cfg = base.replace(**kw)
        with Timer() as t:
            # reuse the trainer but with an overridden config
            hist, params = train("weathermixer-1b", steps=steps,
                                 batch=4, reduced=False, lr=2e-3,
                                 log_every=steps, config_override=cfg)
        # validation on held-out steps
        ds = WeatherDataset(WeatherDataConfig(
            lat=cfg.wm_lat, lon=cfg.wm_lon, channels=cfg.wm_channels,
            seed=0))
        ev = make_eval_step(cfg, SH.jigsaw_for(cfg))
        vals = []
        for s in range(1000, 1004):
            b = {k: np.asarray(v) for k, v in ds.sample_batch(s, 4).items()}
            vals.append(float(ev(params, b)["loss"]))
        val = float(np.mean(vals))
        finals[name] = val
        rows.append((f"fig3/{name}", int(t.seconds * 1e6 / steps),
                     f"params_M={cfg.param_count() / 1e6:.2f}"
                     f"|val_loss={val:.4f}"))
    mono = finals["large"] < finals["medium"] < finals["small"]
    rows.append(("fig3/scaling_claim", 0,
                 f"larger_is_better={mono}"))
    return rows


if __name__ == "__main__":
    emit(run())
