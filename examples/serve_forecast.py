"""Production forecast serving, end to end: train a tiny WeatherMixer
on an 8-way (model=4, data=2) Jigsaw mesh, checkpoint it, then serve
the checkpoint with the continuous-batching ForecastEngine on a
DIFFERENT mesh shape (data-only), with mixed lead times fanning out of
shared rollouts.

  python examples/serve_forecast.py [--requests 12] [--mesh-data 2]
"""
import argparse
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
import tempfile

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--mesh-data", type=int, default=2,
                    help="serving mesh size (!= the 8-way training mesh)")
    ap.add_argument("--steps", type=int, default=10,
                    help="training steps before the checkpoint")
    args = ap.parse_args()

    from repro.configs.registry import get_config
    from repro.launch.engine import EngineConfig, TrainEngine
    from repro.launch.serve import serve

    cfg = get_config("weathermixer-1b").reduced().replace(
        scheme="1d", wm_lat=32, wm_lon=64, d_model=64,
        wm_d_tok=64, wm_d_ch=64)

    ckpt = os.path.join(tempfile.mkdtemp(), "ck")
    print(f"== training on the 8-way (model=4, data=2) mesh -> {ckpt}")
    eng = TrainEngine("weathermixer-1b", reduced=False,
                      config_override=cfg, mesh_model=4, mesh_data=2,
                      scheme="1d",
                      config=EngineConfig(steps=args.steps, batch=4,
                                          log_every=5))
    eng.run()
    eng.save(ckpt, block=True)

    print(f"\n== serving it on a data-only {args.mesh_data}-way mesh")
    results, engine, _ = serve(
        "weathermixer-1b", ckpt=ckpt, requests=args.requests,
        leads=[1, 2, 4, 8], mesh_data=args.mesh_data,
        reduced=False, config_override=cfg, coalesce_ms=5.0)

    # one request with lead-time fan-out: three horizons, one rollout
    fields = np.asarray(results[0].outputs[max(results[0].outputs)])
    r = engine.submit(fields, lead=(1, 4, 8))
    engine.drain()
    print(f"\nfan-out request: horizons {sorted(r.outputs)} peeled from "
          f"one {r.max_lead}-step rollout "
          f"(latency {r.latency() * 1e3:.0f}ms)")
    for lead in sorted(r.outputs):
        f = r.outputs[lead]
        print(f"  +{lead * 6:3d}h forecast: mean={f.mean():+.3f} "
              f"std={f.std():.3f}")
    assert engine.stats["compiles"] == engine.stats["warm_compiles"], \
        "steady-state serving must not recompile"


if __name__ == "__main__":
    main()
