"""End-to-end driver (deliverable b): train a ~100M-parameter WeatherMixer
for a few hundred steps on the synthetic ERA5-like pipeline, with
2-D Jigsaw (the paper's 4-way scheme) on a host-emulated 2x2 model grid.

  python examples/train_weathermixer.py [--steps 300] [--full-100m]

Default runs a reduced model quickly; --full-100m instantiates an actual
~100M-parameter mixer (slower on CPU, identical code path).
"""
import argparse
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--pipeline", default="sharded",
                    choices=["sharded", "sync-full"])
    ap.add_argument("--prefetch", type=int, default=2)
    ap.add_argument("--accum", type=int, default=1)
    args = ap.parse_args()

    import repro.launch.train as T
    from repro.configs.registry import get_config

    if args.full_100m:
        # ~100M params: 3 blocks on a 128x256 grid, d_emb 1024
        cfg = get_config("weathermixer-1b").replace(
            n_layers=3, d_model=1024, wm_lat=128, wm_lon=256,
            wm_channels=24, wm_patch=8, wm_d_tok=2048, wm_d_ch=1024,
            param_dtype="float32", compute_dtype="float32", remat=False,
            scheme="2d")
        print(f"~{cfg.param_count() / 1e6:.0f}M parameter WeatherMixer")
        T.train("weathermixer-1b", steps=args.steps, batch=args.batch,
                reduced=False, mesh_model=4, mesh_data=2, scheme="2d",
                lr=3e-4, ckpt=args.ckpt, config_override=cfg,
                pipeline=args.pipeline, prefetch=args.prefetch,
                accum=args.accum)
    else:
        T.train("weathermixer-1b", steps=args.steps, batch=args.batch,
                reduced=True, mesh_model=4, mesh_data=2, scheme="2d",
                lr=1e-3, ckpt=args.ckpt, pipeline=args.pipeline,
                prefetch=args.prefetch, accum=args.accum)


if __name__ == "__main__":
    main()
