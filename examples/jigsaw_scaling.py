"""Jigsaw parallelism demo: the paper's Fig.-1 story in one script.

Shows, on an 8-device host mesh:
  1. zero memory redundancy: per-device parameter bytes = total / n_model;
  2. the collective schedule of each impl (ring / ring_chunked / rs /
     allreduce / gspmd) on one mixer MLP, from the compiled HLO;
  3. 2-way vs 4-way (1-D vs 2-D/Cannon) numerical equivalence.

  python examples/jigsaw_scaling.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.api import JigsawConfig, mlp_apply, mlp_init
from repro.core.sharding import RULES_2D
from repro.launch.analysis import collective_stats
from repro.launch.mesh import make_host_mesh


def main():
    params = mlp_init(jax.random.PRNGKey(0), 512, 1024, 512)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 256, 512))
    total = sum(v.size * v.dtype.itemsize
                for v in jax.tree.leaves(params))
    ref = mlp_apply(params, x, JigsawConfig(scheme="none"))

    print("== 1-D Jigsaw (paper 2-way, generalized to 4-way) ==")
    mesh = make_host_mesh(model=4, data=2)
    with jax.set_mesh(mesh):
        # shard params jigsaw-style and check per-device bytes
        sharded = {
            k: {kk: jax.device_put(vv, NamedSharding(
                mesh, P(None, "model") if vv.ndim == 2 else P("model")))
                for kk, vv in v.items()} for k, v in params.items()}
        per_dev = sum(
            np.prod(v.sharding.shard_shape(v.shape)) * v.dtype.itemsize
            for v in jax.tree.leaves(sharded))
        print(f"param bytes total={total}  per-device={per_dev}  "
              f"ratio={total / per_dev:.1f} (= n_model: zero redundancy)")
        for impl in ["ring", "ring_chunked", "rs", "allreduce", "gspmd"]:
            cfg = JigsawConfig(impl=impl)
            comp = jax.jit(lambda p, v: mlp_apply(p, v, cfg)).lower(
                sharded, x).compile()
            st = collective_stats(comp.as_text())
            out = jax.jit(lambda p, v: mlp_apply(p, v, cfg))(sharded, x)
            ok = np.allclose(np.asarray(out), np.asarray(ref), rtol=1e-3,
                             atol=1e-4)
            print(f"  impl={impl:9s} == dense: {ok}   "
                  f"collective bytes/dev: {st.total_bytes:9.0f}  "
                  f"{ {k: v for k, v in st.counts.items() if v} }")

    print("\n== 2-D Jigsaw (paper 4-way, Cannon 2x2) ==")
    mesh2 = make_host_mesh(model=4, data=2, two_d=True)
    with jax.set_mesh(mesh2):
        cfg2 = JigsawConfig(rules=RULES_2D, scheme="2d")
        out2 = jax.jit(lambda p, v: mlp_apply(p, v, cfg2))(params, x)
        comp = jax.jit(lambda p, v: mlp_apply(p, v, cfg2)).lower(
            params, x).compile()
        st = collective_stats(comp.as_text())
        print(f"  cannon 2x2 == dense: "
              f"{np.allclose(np.asarray(out2), np.asarray(ref), rtol=1e-3, atol=1e-4)}"
              f"   collective bytes/dev: {st.total_bytes:.0f}  "
              f"{ {k: v for k, v in st.counts.items() if v} }")

    print("\n== Domain-parallel input pipeline (paper §5) ==")
    # thin TrainEngine caller: each model-parallel rank generates only its
    # (lon x channel) slice; a background thread prefetches ahead of
    # compute.  Same seed => identical losses to the legacy sync path.
    from repro.launch.engine import EngineConfig, TrainEngine
    hist = {}
    for mode, pf in [("sync-full", 0), ("sharded", 2)]:
        eng = TrainEngine("weathermixer-1b", mesh_model=4, mesh_data=2,
                          scheme="1d",
                          config=EngineConfig(steps=4, batch=4,
                                              log_every=3, pipeline=mode,
                                              prefetch=pf))
        hist[mode] = eng.run()
        per_rank = max(eng.pipeline.stats.rank_bytes.get(
            "fields", {0: 0}).values())
        print(f"  mode={mode:10s} final loss "
              f"{hist[mode][-1]['loss']:.6f}  host bytes/rank/run "
              f"{per_rank}")
    same = np.allclose(hist["sync-full"][-1]["loss"],
                       hist["sharded"][-1]["loss"], rtol=1e-6)
    print(f"  sharded+prefetch == sync-full losses: {same}")


if __name__ == "__main__":
    main()
