"""Quickstart: build a Jigsaw-parallel model, run a forward pass, inspect
the sharding.  Runs on CPU with 8 emulated devices.

  python examples/quickstart.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_config
from repro.launch import shapes as SH
from repro.launch.mesh import make_host_mesh
from repro.models import registry as M


def main():
    print("assigned architectures:", ", ".join(ARCH_IDS))

    # 1. pick an architecture, reduce it to laptop scale
    cfg = get_config("internlm2-1.8b").reduced().replace(scheme="1d")
    print(f"\narch={cfg.arch_id} family={cfg.family} "
          f"params~{cfg.param_count() / 1e6:.1f}M (reduced)")

    # 2. a (data=2, model=4) mesh: the model axis carries 1-D Jigsaw --
    #    every weight sharded along its contracting dim, zero redundancy
    mesh = make_host_mesh(model=4, data=2)
    jcfg = SH.jigsaw_for(cfg)

    params = M.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                cfg.vocab_size)

    with jax.set_mesh(mesh):
        logits, _ = jax.jit(
            lambda p, b: M.apply(p, b, cfg, jcfg))(params,
                                                   {"tokens": tokens})
    print(f"logits: {logits.shape} {logits.dtype}")
    print(f"logit sharding: {logits.sharding}")

    # 3. the same model runs dense (scheme='none') -- bitwise-comparable
    ref, _ = M.apply(params, {"tokens": tokens}, cfg,
                     jcfg.replace(scheme="none", impl="gspmd"))
    import numpy as np
    print("jigsaw == dense:",
          np.allclose(np.asarray(logits), np.asarray(ref), rtol=1e-3,
                      atol=1e-3))


if __name__ == "__main__":
    main()
