"""Serve a small LM with batched requests: FUSED prefill (one apply
captures every layer's K/V) + batched greedy decode through the
compile-once, cache-donating serve step (serve/step.py).

  python examples/serve_lm.py [--arch stablelm-3b] [--steps 24]

For forecast-model serving (continuous batching, lead-time fan-out),
see examples/serve_forecast.py.
"""
import argparse
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--train-first", type=int, default=60,
                    help="train briefly so generations are non-trivial")
    args = ap.parse_args()

    import time

    import jax
    from repro.configs.registry import get_config
    from repro.data.tokens import TokenDataConfig, TokenDataset
    from repro.launch import shapes as SH
    from repro.launch.train import train
    from repro.serve.step import generate, jit_serve_step, prefill

    # quick training so the model predicts the affine-walk structure
    _, params = train(args.arch, steps=args.train_first, batch=8,
                      seq_len=64, reduced=True, lr=2e-3, log_every=30)
    cfg = get_config(args.arch).reduced()
    jcfg = SH.jigsaw_for(cfg)

    ds = TokenDataset(TokenDataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=16, seed=123))
    prompts = jax.numpy.asarray(
        ds.sample_batch(0, args.batch)["tokens"][:, :8])
    max_len = 8 + args.steps + 2

    # fused prefill = one forward; token-wise = one decode step per
    # prompt token (kept as the parity reference)
    for fused, tag in ((True, "fused"), (False, "token-wise")):
        t0 = time.perf_counter()
        nxt, _ = prefill(params, prompts, cfg, jcfg, max_len, fused=fused)
        jax.block_until_ready(nxt)
        print(f"prefill[{tag:>10}]: {time.perf_counter() - t0:.2f}s "
              f"-> next tokens {np.asarray(nxt).ravel()}")

    out = generate(params, prompts, cfg, jcfg, steps=args.steps,
                   max_len=max_len)
    # the decode step is lru-cached by (cfg, jcfg): a second generate
    # reuses the same executable (and donates the cache every step)
    assert jit_serve_step(cfg, jcfg)._cache_size() == 1
    # the data's affine walk: next = (31 x + 17) % V; measure how often
    # the model follows it (vs 1/V for random)
    seq = np.concatenate([np.asarray(prompts), np.asarray(out)], axis=1)
    pred = (seq[:, :-1] * 31 + 17) % cfg.vocab_size
    acc = float((pred == seq[:, 1:]).mean())
    print(f"\nbatched generation: {out.shape}")
    for row in np.asarray(out)[:2]:
        print("  tokens:", row[:16], "...")
    print(f"affine-walk consistency of generations: {acc:.2f} "
          f"(random would be {1 / cfg.vocab_size:.4f})")


if __name__ == "__main__":
    main()
