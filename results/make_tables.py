"""Convert dryrun JSONL records into the EXPERIMENTS.md roofline tables."""
import json
import sys
from collections import defaultdict


def load(path):
    recs = []
    with open(path) as f:
        for line in f:
            if line.strip():
                recs.append(json.loads(line))
    # keep the LAST record per key (re-runs supersede)
    by_key = {}
    for r in recs:
        by_key[(r["arch"], r["shape"], r["multi_pod"],
                r.get("scheme"), r.get("impl"))] = r
    return list(by_key.values())


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    return f"{x * 1e3:.2f}ms"


def roofline_table(recs, multi_pod=False):
    rows = ["| arch | shape | compute | memory | collective | bottleneck "
            "| useful% | fits HBM | arg+temp GiB/dev |",
            "|---|---|---|---|---|---|---|---|---|"]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    sel = [r for r in recs if r["multi_pod"] == multi_pod]
    sel.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    for r in sel:
        if r["status"] == "SKIP":
            rows.append(f"| {r['arch']} | {r['shape']} | SKIP | | | "
                        f"{r['reason'][:60]} | | | |")
            continue
        if r["status"] == "FAIL":
            rows.append(f"| {r['arch']} | {r['shape']} | FAIL | | | "
                        f"{r.get('error', '')[:60]} | | | |")
            continue
        gib = r["arg_gib"] + r["temp_gib"] + r["out_gib"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"**{r['bottleneck']}** | {r['useful_ratio'] * 100:.0f} | "
            f"{'yes' if r['fits_hbm'] else 'NO'} | {gib:.2f} |")
    return "\n".join(rows)


def summary(recs):
    ok = sum(r["status"] == "OK" for r in recs)
    skip = sum(r["status"] == "SKIP" for r in recs)
    fail = sum(r["status"] == "FAIL" for r in recs)
    return f"{ok} OK / {skip} SKIP (documented) / {fail} FAIL"


if __name__ == "__main__":
    recs = load(sys.argv[1] if len(sys.argv) > 1
                else "results/dryrun_baseline.jsonl")
    print("## Summary:", summary(recs))
    print("\n### Single-pod (16x16 = 256 chips)\n")
    print(roofline_table(recs, multi_pod=False))
    print("\n### Multi-pod (2x16x16 = 512 chips)\n")
    print(roofline_table(recs, multi_pod=True))
