"""Architecture registry: family -> model module dispatch."""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.api import JigsawConfig
from repro.models import encdec, hybrid, mamba, transformer, weathermixer

_FAMILY_MODULE = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": mamba,
    "hybrid": hybrid,
    "audio": encdec,
    "mixer": weathermixer,
}


def module_for(cfg: ModelConfig):
    return _FAMILY_MODULE[cfg.family]


def init(key: jax.Array, cfg: ModelConfig):
    return module_for(cfg).init(key, cfg)


def apply(params, batch, cfg: ModelConfig, jcfg: JigsawConfig, **kw):
    return module_for(cfg).apply(params, batch, cfg, jcfg, **kw)


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               dtype=jnp.bfloat16):
    mod = module_for(cfg)
    if not hasattr(mod, "init_cache"):
        raise ValueError(f"{cfg.arch_id} ({cfg.family}) has no decode path")
    return mod.init_cache(cfg, batch_size, max_len, dtype)


def decode_step(params, cache, tokens, cfg: ModelConfig, jcfg: JigsawConfig):
    return module_for(cfg).decode_step(params, cache, tokens, cfg, jcfg)


def prefill_cache(params, batch, cfg: ModelConfig, jcfg: JigsawConfig,
                  max_len: int, dtype=jnp.bfloat16):
    """Fused prefill: one teacher-forced forward + KV write-back.
    Families without one raise NotImplementedError -- callers
    (serve/step.py) fall back to the token-wise reference path."""
    mod = module_for(cfg)
    if not hasattr(mod, "prefill_cache"):
        raise NotImplementedError(
            f"{cfg.arch_id} ({cfg.family}) has no fused prefill")
    return mod.prefill_cache(params, batch, cfg, jcfg, max_len, dtype=dtype)


def forecast_step(params, fields, cfg: ModelConfig, jcfg: JigsawConfig):
    """One autoregressive field-rollout step (serving hot path)."""
    mod = module_for(cfg)
    if not hasattr(mod, "forecast_step"):
        raise ValueError(f"{cfg.arch_id} ({cfg.family}) has no "
                         "autoregressive forecast step")
    return mod.forecast_step(params, fields, cfg, jcfg)
