"""Shared neural building blocks (pure-functional, pytree params).

All layers follow the convention:
    *_init(key, ...) -> param dict
    *_apply(params, x, ...) -> output

Linear layers route through the Jigsaw API (repro.core.api) so the paper's
parallelism is a first-class feature of every architecture.  Norms are
computed in float32 and cast back.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.api import (DEFAULT_JIGSAW, JigsawConfig, linear_apply,
                            linear_init, mlp_apply, mlp_init)


def boundary_cast(x: jax.Array, cfg: JigsawConfig) -> jax.Array:
    """Cast a model-entry tensor (pipeline fields, frontend embeds) to the
    policy compute dtype so the whole residual stream -- not just the GEMM
    operands -- carries it (half the activation bytes under bf16).  The
    norms below then keep it: they compute in f32 and cast back to
    ``x.dtype``.  No-op when no compute dtype is set (legacy)."""
    if cfg.compute_dtype is None:
        return x
    return x.astype(cfg.compute_dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm_apply(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0):
    """x: [B, S, H, hd]; positions: [B, S] or [S]. Rotates pairs (even, odd
    halves convention, as llama)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = jnp.arange(half, dtype=jnp.float32)
    inv = 1.0 / (theta ** (freq / half))
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * inv  # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA; full-causal / sliding-window / bidirectional / cross)
# ---------------------------------------------------------------------------

def attention_init(key, d_model: int, n_heads: int, n_kv_heads: int,
                   d_head: int, *, dtype=jnp.float32, bias: bool = False,
                   fused_qkv: bool = False):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": linear_init(kq, d_model, n_heads * d_head, dtype=dtype, bias=bias),
        "wk": linear_init(kk, d_model, n_kv_heads * d_head, dtype=dtype, bias=bias),
        "wv": linear_init(kv, d_model, n_kv_heads * d_head, dtype=dtype, bias=bias),
        "wo": linear_init(ko, n_heads * d_head, d_model, dtype=dtype, bias=bias),
    }


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """[B, S, Hkv, hd] -> [B, S, Hkv*n_rep, hd] grouping-compatible."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)
                            ).reshape(b, s, h * n_rep, d)


def sdpa(q: jax.Array, k: jax.Array, v: jax.Array, *,
         q_pos: jax.Array, kv_pos: jax.Array, causal: bool = True,
         window: Optional[int] = None, kv_mask: Optional[jax.Array] = None,
         soft_cap: Optional[float] = None) -> jax.Array:
    """Scaled dot-product attention with GQA repeat handled by caller.

    q: [B, Sq, H, hd]; k, v: [B, Skv, H, hd].
    q_pos: [B, Sq] absolute positions of queries.
    kv_pos: [B, Skv] absolute positions of keys (cache slots may be
            rolling for sliding-window caches).
    kv_mask: [B, Skv] optional validity mask for cache slots.
    """
    hd = q.shape[-1]
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if soft_cap is not None:
        scores = jnp.tanh(scores / soft_cap) * soft_cap
    # Build the mask batch-free when positions are batch-independent
    # ([Sq]/[Skv] 1-D) so it materializes as [Sq, Skv], not [B, Sq, Skv]
    # -- at (B=256, S=4096) the difference is ~270 GiB/device.
    dq = q_pos[..., :, None]            # [.., Sq, 1]
    dk = kv_pos[..., None, :]           # [.., 1, Skv]
    mask = None
    if causal:
        mask = dk <= dq
    if window is not None:
        m = dq - dk < window
        mask = m if mask is None else mask & m
    if kv_mask is not None:
        m = jnp.broadcast_to(kv_mask[..., None, :], kv_mask.shape[:-1]
                             + (dq.shape[-2], kv_mask.shape[-1]))
        mask = m if mask is None else mask & m
    if mask is not None:
        mask = mask[None, None] if mask.ndim == 2 else mask[:, None]
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return out


def sdpa_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                 q_pos: jax.Array, kv_pos: jax.Array, causal: bool = True,
                 window=None, q_chunk: int = 512,
                 kv_chunk: int = 1024) -> jax.Array:
    """Memory-bounded attention: query-chunked with an online-softmax
    scan over key/value chunks (flash-attention recurrence at the XLA
    level).  Peak score buffer is [B, H, q_chunk, kv_chunk] instead of
    [B, H, Sq, Skv] -- the fix for the f32 score tensors that dominated
    the 4k-train / 32k-prefill dry-run temps (EXPERIMENTS.md #Perf).

    Restrictions vs ``sdpa``: 1-D positions only (train/prefill), no
    kv_mask / soft_cap (those paths keep the exact reference).
    """
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    assert q_pos.ndim == 1 and kv_pos.ndim == 1
    scale = 1.0 / math.sqrt(hd)
    nq = -(-sq // q_chunk)
    nk = -(-skv // kv_chunk)
    q_pad, kv_pad = nq * q_chunk - sq, nk * kv_chunk - skv
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, q_pad), constant_values=-(2 ** 30))
    if kv_pad:
        k = jnp.pad(k, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, kv_pad), constant_values=2 ** 30)
    qc = q.reshape(b, nq, q_chunk, h, hd).transpose(1, 0, 3, 2, 4)
    kc = k.reshape(b, nk, kv_chunk, h, hd).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nk, kv_chunk, h, hd).transpose(1, 0, 3, 2, 4)
    qp = q_pos.reshape(nq, q_chunk)
    kp = kv_pos.reshape(nk, kv_chunk)

    def one_q_chunk(args):
        qi, qpi = args                       # [B,H,Qc,hd], [Qc]

        def kv_step(carry, inp):
            m, l, acc = carry
            ki, vi, kpi = inp
            s = jnp.einsum("bhqd,bhkd->bhqk", qi, ki,
                           preferred_element_type=jnp.float32) * scale
            msk = None
            if causal:
                msk = kpi[None, :] <= qpi[:, None]
            if window is not None:
                mw = qpi[:, None] - kpi[None, :] < window
                msk = mw if msk is None else msk & mw
            if msk is not None:
                s = jnp.where(msk[None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vi.dtype), vi)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kc, vc, kp))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.astype(q.dtype)           # [B,H,Qc,hd]

    outs = jax.lax.map(jax.checkpoint(one_q_chunk), (qc, qp))  # [nq,B,H,Qc,hd]
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, nq * q_chunk, h, hd)
    return out[:, :sq]


def attention_apply(params, x: jax.Array, *, n_heads: int, n_kv_heads: int,
                    d_head: int, positions: jax.Array,
                    cfg: JigsawConfig = DEFAULT_JIGSAW,
                    causal: bool = True, window: Optional[int] = None,
                    rope_theta: Optional[float] = 10000.0,
                    soft_cap: Optional[float] = None,
                    kv_cache: Optional[dict] = None,
                    rolling: bool = False,
                    collect_kv: bool = False,
                    kv_spec=None,
                    x_kv: Optional[jax.Array] = None,
                    qk_norm: Optional[dict] = None,
                    q_chunk: int = 0) -> Tuple[jax.Array, Optional[dict]]:
    """General attention layer.

    Training/prefill: x [B, S, D], positions [B, S], kv_cache None.
    Decode: x [B, 1, D]; kv_cache = {"k": [B, S_max, Hkv, hd], "v": ...,
            "pos": [B] next write offset}; returns updated cache.
    Cross-attention: pass x_kv (encoder states); no cache, no causal.
    """
    b, s, _ = x.shape
    xkv = x if x_kv is None else x_kv
    q = linear_apply(params["wq"], x, cfg).reshape(b, s, n_heads, d_head)
    k = linear_apply(params["wk"], xkv, cfg).reshape(b, xkv.shape[1], n_kv_heads, d_head)
    v = linear_apply(params["wv"], xkv, cfg).reshape(b, xkv.shape[1], n_kv_heads, d_head)

    if qk_norm is not None:
        q = rmsnorm_apply(qk_norm["q"], q)
        k = rmsnorm_apply(qk_norm["k"], k)

    if rope_theta is not None and x_kv is None:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)

    new_cache = None
    if kv_cache is not None:
        # Decode step: append k,v at (rolling) slot, attend over the cache.
        s_max = kv_cache["k"].shape[1]
        pos = kv_cache["pos"]                         # [B]
        slot = pos % s_max if rolling else jnp.minimum(pos, s_max - 1)
        bidx = jnp.arange(b)
        ck = jax.lax.stop_gradient(kv_cache["k"])
        cv = jax.lax.stop_gradient(kv_cache["v"])
        ck = ck.at[bidx, slot].set(k[:, 0].astype(ck.dtype))
        cv = cv.at[bidx, slot].set(v[:, 0].astype(cv.dtype))
        # absolute positions of cache slots
        slot_idx = jnp.arange(s_max)[None, :]
        if rolling:
            # rolling window cache: slot i holds absolute position
            # pos - ((slot - i) % s_max)
            kv_pos = pos[:, None] - ((slot[:, None] - slot_idx) % s_max)
        else:
            kv_pos = jnp.broadcast_to(slot_idx, (b, s_max))
        kv_mask = (kv_pos >= 0) & (kv_pos <= pos[:, None])
        if kv_spec is not None:
            # pin the cache layout through the update + repeat: without
            # this GSPMD "involuntarily rematerializes" (fully gathers)
            # an S-sharded cache to reshard it by heads -- 80 GiB/step
            # for dbrx decode_32k.  Keeping S sharded makes the softmax
            # a flash-decoding partial reduction instead.
            from repro.core.sharding import constrain as _constrain
            ck = _constrain(ck, kv_spec)
            cv = _constrain(cv, kv_spec)
        kk = _repeat_kv(ck.astype(q.dtype), n_heads // n_kv_heads)
        vv = _repeat_kv(cv.astype(q.dtype), n_heads // n_kv_heads)
        if kv_spec is not None:
            from repro.core.sharding import constrain as _constrain
            kk = _constrain(kk, kv_spec)
            vv = _constrain(vv, kv_spec)
        out = sdpa(q, kk, vv, q_pos=positions, kv_pos=kv_pos, causal=True,
                   window=window, kv_mask=kv_mask, soft_cap=soft_cap)
        new_cache = {"k": ck, "v": cv, "pos": pos + 1}
    else:
        if collect_kv:
            # fused prefill (serve/step.py): hand every prompt position's
            # post-RoPE, pre-GQA-repeat K/V back for cache write-back --
            # exactly what the decode branch above would have cached one
            # token at a time
            new_cache = {"k": k, "v": v}
        kk = _repeat_kv(k, n_heads // n_kv_heads)
        vv = _repeat_kv(v, n_heads // n_kv_heads)
        kv_positions = positions if x_kv is None else \
            jnp.arange(xkv.shape[1])
        if q_chunk and positions.ndim == 1 and soft_cap is None:
            # beyond-paper: online-softmax chunked attention (see #Perf)
            out = sdpa_chunked(q, kk, vv, q_pos=positions,
                               kv_pos=kv_positions,
                               causal=causal and x_kv is None,
                               window=window, q_chunk=q_chunk)
        else:
            out = sdpa(q, kk, vv, q_pos=positions, kv_pos=kv_positions,
                       causal=causal and x_kv is None, window=window,
                       soft_cap=soft_cap)

    out = out.reshape(b, s, n_heads * d_head)
    out = linear_apply(params["wo"], out, cfg)
    return out, new_cache


# ---------------------------------------------------------------------------
# Feed-forward variants
# ---------------------------------------------------------------------------

def ffn_init(key, d_model: int, d_ff: int, *, kind: str = "swiglu",
             dtype=jnp.float32, bias: bool = False):
    if kind == "swiglu":
        kg, ku, kd = jax.random.split(key, 3)
        return {"gate": linear_init(kg, d_model, d_ff, dtype=dtype, bias=bias),
                "up": linear_init(ku, d_model, d_ff, dtype=dtype, bias=bias),
                "down": linear_init(kd, d_ff, d_model, dtype=dtype, bias=bias)}
    if kind == "gelu":
        return mlp_init(key, d_model, d_ff, d_model, dtype=dtype, bias=bias)
    raise ValueError(kind)


def ffn_apply(params, x, cfg: JigsawConfig = DEFAULT_JIGSAW):
    if "gate" in params:
        g = linear_apply(params["gate"], x, cfg)
        u = linear_apply(params["up"], x, cfg)
        h = jax.nn.silu(g) * u
        return linear_apply(params["down"], h, cfg)
    return mlp_apply({"fc1": params["fc1"], "fc2": params["fc2"]}, x, cfg)


# ---------------------------------------------------------------------------
# Mixture of Experts (top-k router, capacity-based einsum dispatch; GShard
# style so expert parallelism lowers to all-to-all-like collectives)
# ---------------------------------------------------------------------------

def moe_init(key, d_model: int, d_ff: int, n_experts: int, *,
             kind: str = "swiglu", dtype=jnp.float32):
    kr, ke = jax.random.split(key)
    router = linear_init(kr, d_model, n_experts, dtype=jnp.float32, bias=False)
    scale = 1.0 / math.sqrt(d_model)
    keys = jax.random.split(ke, 3)
    if kind == "swiglu":
        experts = {
            "gate": jax.random.normal(keys[0], (n_experts, d_ff, d_model)) * scale,
            "up": jax.random.normal(keys[1], (n_experts, d_ff, d_model)) * scale,
            "down": jax.random.normal(keys[2], (n_experts, d_model, d_ff))
                    * (1.0 / math.sqrt(d_ff)),
        }
    else:
        experts = {
            "fc1": jax.random.normal(keys[0], (n_experts, d_ff, d_model)) * scale,
            "fc2": jax.random.normal(keys[1], (n_experts, d_model, d_ff))
                   * (1.0 / math.sqrt(d_ff)),
        }
    experts = {k: v.astype(dtype) for k, v in experts.items()}
    return {"router": router, "experts": experts}


def moe_apply(params, x: jax.Array, *, top_k: int, capacity_factor: float = 1.25,
              cfg: JigsawConfig = DEFAULT_JIGSAW,
              group_size: int = 1024) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, aux_loss).  x: [B, S, D].

    GShard-style *grouped* dispatch: tokens are split into groups of
    ``group_size`` and routed independently within each group with
    per-group capacity C = cf*k*group/E, so the dispatch one-hot is
    [G, group, E, C] -- LINEAR in total tokens.  (An ungrouped [T, E, C]
    dispatch is quadratic in T and produced ~2.7 TiB/device temps in the
    first dbrx train_4k dry-run.)  Groups follow token order, so the
    group dim inherits the batch sharding; with experts sharded over the
    model axis the dispatch einsum is the expert-parallel all-to-all.
    """
    b, s, d = x.shape
    e = params["router"]["w"].shape[0]
    t = b * s
    xt = x.reshape(t, d)
    gs = min(group_size, t)
    pad = (-t) % gs
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    g = xt.shape[0] // gs
    xg = xt.reshape(g, gs, d)

    logits = linear_apply(params["router"], xg.astype(jnp.float32),
                          cfg.replace(scheme="none"))          # [G, gs, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)           # [G, gs, k]
    # normalize selected gates (dbrx/mixtral convention)
    gate_vals = gate_vals / jnp.clip(jnp.sum(gate_vals, -1, keepdims=True),
                                     1e-9)

    capacity = max(1, int(capacity_factor * top_k * gs / e))
    # position of each (token, k) within its expert's per-group buffer
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)      # [G, gs, k, E]
    flat = onehot.reshape(g, gs * top_k, e)
    pos_in_exp = (jnp.cumsum(flat, axis=1) - flat).reshape(g, gs, top_k, e)
    pos = jnp.sum(pos_in_exp * onehot, axis=-1)                 # [G, gs, k]
    keep = pos < capacity

    # load-balance auxiliary loss (Switch/GShard), global over all groups
    me = jnp.mean(probs, axis=(0, 1))                           # [E]
    ce = jnp.mean(jnp.sum(onehot, axis=2).astype(jnp.float32), axis=(0, 1))
    aux = e * jnp.sum(me * ce)

    # dispatch/combine [G, gs, E, C], accumulated per routing slot k so the
    # 5-D [G, gs, k, E, C] intermediate never materializes
    dispatch = jnp.zeros((g, gs, e, capacity), x.dtype)
    combine = jnp.zeros((g, gs, e, capacity), x.dtype)
    for kk in range(top_k):
        term = (jax.nn.one_hot(gate_idx[..., kk], e, dtype=x.dtype)[..., None]
                * jax.nn.one_hot(pos[..., kk], capacity,
                                 dtype=x.dtype)[..., None, :])
        term = term * keep[..., kk, None, None].astype(x.dtype)
        dispatch = dispatch + term
        combine = combine + term * gate_vals[..., kk, None, None].astype(
            x.dtype)

    xe = jnp.einsum("gtec,gtd->gecd", dispatch, xg)             # [G, E, C, D]
    w = params["experts"]
    if "gate" in w:
        gt = jnp.einsum("gecd,efd->gecf", xe, w["gate"])
        u = jnp.einsum("gecd,efd->gecf", xe, w["up"])
        h = jax.nn.silu(gt) * u
        ye = jnp.einsum("gecf,edf->gecd", h, w["down"])
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,efd->gecf", xe, w["fc1"]))
        ye = jnp.einsum("gecf,edf->gecd", h, w["fc2"])
    yt = jnp.einsum("gtec,gecd->gtd", combine, ye)              # [G, gs, D]
    yt = yt.reshape(g * gs, d)
    if pad:
        yt = yt[:t]
    return yt.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Mamba-2 (SSD — state-space duality, arXiv:2405.21060)
# ---------------------------------------------------------------------------

def mamba2_init(key, d_model: int, *, d_state: int = 128, n_heads: int = 24,
                head_dim: int = 64, conv_kernel: int = 4, n_groups: int = 1,
                expand: int = 2, dtype=jnp.float32):
    d_inner = n_heads * head_dim
    assert d_inner == expand * d_model, \
        f"mamba2: n_heads*head_dim ({d_inner}) must equal expand*d_model"
    conv_dim = d_inner + 2 * n_groups * d_state
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    # The input projection is SPLIT into its [z | xBC | dt] slices: the
    # fused width (2*d_inner + 2*g*N + H = e.g. 3352) is not divisible by
    # the 16-way model axis, which forced GSPMD to complete the matmul
    # with a full [B,S,3352] f32 ALLREDUCE (2x19.6 GiB/step for
    # mamba2-130m train_4k, EXPERIMENTS.md #Perf D).  The z and xBC
    # widths shard evenly; the tiny dt head (H cols) replicates.
    p = {
        "in_z": linear_init(k1, d_model, d_inner, dtype=dtype, bias=False),
        "in_xbc": linear_init(k5, d_model, conv_dim, dtype=dtype,
                              bias=False),
        "in_dt": linear_init(k6, d_model, n_heads, dtype=dtype,
                             bias=False),
        "conv_w": (jax.random.normal(k2, (conv_kernel, conv_dim))
                   * (1.0 / math.sqrt(conv_kernel))).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm": rmsnorm_init(d_inner, dtype),
        "out_proj": linear_init(k3, d_inner, d_model, dtype=dtype, bias=False),
    }
    return p


def _ssd_chunked(x, dt, A, B, C, chunk: int):
    """SSD chunked scan (Mamba-2 Listing-style, pure jnp).

    x: [b, s, h, p]; dt: [b, s, h] (post-softplus); A: [h] (negative);
    B, C: [b, s, g, n] with g groups broadcast to h.
    Returns y: [b, s, h, p] and final state [b, h, p, n].
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2)      # [b, s, h, n]
    Ch = jnp.repeat(C, rep, axis=2)
    if s % chunk != 0:
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bh = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Ch = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = x.shape[1]
    nc = sp // chunk

    def ck(t):  # [b, s, ...] -> [b, nc, chunk, ...]
        return t.reshape((b, nc, chunk) + t.shape[2:])

    xc, dtc, Bc, Cc = ck(x), ck(dt), ck(Bh), ck(Ch)
    dA = dtc * A[None, None, None, :]                       # [b,nc,l,h] (<=0)
    dA_cum = jnp.cumsum(dA, axis=2)                         # within-chunk
    # intra-chunk (the "attention-like" quadratic term)
    # L[i,j] = exp(dA_cum[i] - dA_cum[j]) for i >= j
    seg = dA_cum[:, :, :, None, :] - dA_cum[:, :, None, :, :]   # [b,nc,l,l,h]
    li = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(li[None, None, :, :, None], jnp.exp(seg), 0.0)
    CB = jnp.einsum("bzihn,bzjhn->bzijh", Cc, Bc)           # [b,nc,l,l,h]
    att = CB * L * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bzijh,bzjhp->bzihp", att, xc)

    # chunk states: sum_j exp(dA_cum[end] - dA_cum[j]) dt_j B_j x_j^T
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)   # [b,nc,l,h]
    states = jnp.einsum("bzlh,bzlhn,bzlhp->bzhpn",
                        decay_to_end * dtc, Bc, xc)         # [b,nc,h,p,n]
    # inter-chunk recurrence over nc
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])              # [b,nc,h]

    def scan_fn(h_prev, inp):
        dec, st = inp
        h_new = h_prev * dec[:, :, None, None] + st
        return h_new, h_prev

    h0 = jnp.zeros((b, h, p, n), x.dtype)
    hT, h_prevs = jax.lax.scan(
        scan_fn, h0, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                   # [b,nc,h,p,n]
    # contribution of carried state to each position
    state_decay = jnp.exp(dA_cum)                           # [b,nc,l,h]
    y_inter = jnp.einsum("bzlhn,bzhpn,bzlh->bzlhp", Cc, h_prevs, state_decay)
    y = (y_intra + y_inter).reshape(b, sp, h, p)[:, :s]
    return y, hT


def mamba2_apply(params, x: jax.Array, *, d_state: int, n_heads: int,
                 head_dim: int, n_groups: int = 1, conv_kernel: int = 4,
                 chunk: int = 64, cfg: JigsawConfig = DEFAULT_JIGSAW,
                 state: Optional[dict] = None) -> Tuple[jax.Array, Optional[dict]]:
    """Mamba-2 mixer.  Train/prefill: state=None. Decode: state dict with
    {"conv": [B, K-1, conv_dim], "ssm": [B, H, P, N]} -> returns updated."""
    b, s, d = x.shape
    d_inner = n_heads * head_dim
    z = linear_apply(params["in_z"], x, cfg)
    xBC = linear_apply(params["in_xbc"], x, cfg)
    dt = linear_apply(params["in_dt"], x, cfg)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"])

    new_state = None
    if state is None:
        # causal depthwise conv over sequence
        cw = params["conv_w"]                                # [K, conv_dim]
        k = cw.shape[0]
        xp = jnp.pad(xBC, ((0, 0), (k - 1, 0), (0, 0)))
        conv = sum(xp[:, i:i + s, :] * cw[i][None, None, :] for i in range(k))
        xBC = jax.nn.silu(conv + params["conv_b"][None, None, :])
        xs, B, C = jnp.split(xBC, [d_inner, d_inner + n_groups * d_state],
                             axis=-1)
        xs = xs.reshape(b, s, n_heads, head_dim)
        B = B.reshape(b, s, n_groups, d_state)
        C = C.reshape(b, s, n_groups, d_state)
        y, _ = _ssd_chunked(xs.astype(jnp.float32), dt, A,
                            B.astype(jnp.float32), C.astype(jnp.float32),
                            chunk)
        y = y + xs.astype(jnp.float32) * params["D"][None, None, :, None]
    else:
        # single-token decode
        cw = params["conv_w"]
        k = cw.shape[0]
        conv_state = state["conv"]                           # [B, K-1, conv]
        window = jnp.concatenate([conv_state, xBC], axis=1)  # [B, K, conv]
        conv = jnp.einsum("bkc,kc->bc", window, cw)[:, None, :]
        xBC = jax.nn.silu(conv + params["conv_b"][None, None, :])
        xs, B, C = jnp.split(xBC, [d_inner, d_inner + n_groups * d_state],
                             axis=-1)
        xs = xs.reshape(b, 1, n_heads, head_dim).astype(jnp.float32)
        B = B.reshape(b, 1, n_groups, d_state).astype(jnp.float32)
        C = C.reshape(b, 1, n_groups, d_state).astype(jnp.float32)
        rep = n_heads // n_groups
        Bh = jnp.repeat(B[:, 0], rep, axis=1)                # [B, H, N]
        Ch = jnp.repeat(C[:, 0], rep, axis=1)
        dA = jnp.exp(dt[:, 0, :] * A[None, :])               # [B, H]
        ssm = state["ssm"].astype(jnp.float32)               # [B, H, P, N]
        upd = jnp.einsum("bh,bhn,bhp->bhpn", dt[:, 0], Bh, xs[:, 0])
        ssm_new = ssm * dA[:, :, None, None] + upd
        y = jnp.einsum("bhn,bhpn->bhp", Ch, ssm_new)[:, None]
        y = y + xs * params["D"][None, None, :, None]
        new_state = {"conv": window[:, 1:], "ssm": ssm_new.astype(state["ssm"].dtype)}

    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm_apply(params["norm"], y)
    out = linear_apply(params["out_proj"], y, cfg)
    return out, new_state


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d_model: int, dtype=jnp.float32):
    tbl = jax.random.normal(key, (vocab, d_model)) * (1.0 / math.sqrt(d_model))
    return {"table": tbl.astype(dtype)}


def embed_apply(params, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["table"], tokens, axis=0)


def unembed_apply(params_embed, x: jax.Array,
                  cfg: JigsawConfig = DEFAULT_JIGSAW) -> jax.Array:
    """Tied LM head: logits = x @ table.T (a Jigsaw linear over d_model).
    Uses the GSPMD head config -- see api.head_config for why."""
    from repro.core.api import head_config
    return linear_apply({"w": params_embed["table"]}, x, head_config(cfg))
