"""jamba-1.5-large: hybrid Mamba + attention with MoE (arXiv:2403.19887).

Layer pattern: 1 attention layer per ``attn_every`` (=8, the paper's 1:7
interleave), MoE FFN on every other layer (``moe_every=2``), dense FFN
otherwise.  The 72 layers are 9 repeats of an 8-layer "period"; we scan
over periods with the period unrolled inside the body, so the HLO is
O(period) and layer order is exact.

NOTE (DESIGN.md §Arch-applicability): Jamba-1.5 uses Mamba-1 internally;
we instantiate our Mamba-2 (SSD) mixer as the family representative --
same recurrence structure, TPU-friendlier chunked scan.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.api import DEFAULT_JIGSAW, JigsawConfig
from repro.core.sharding import constrain
from repro.models import layers as L
from repro.models.transformer import (FULL_WINDOW, _kv_spec, _layer_apply,
                                      _norm_apply)


def _slot_kind(cfg: ModelConfig, j: int) -> str:
    return "attn" if cfg.is_attn_layer(j) else "ssm"


def period_init(key: jax.Array, cfg: ModelConfig):
    """Params for one period (attn_every layers), heterogeneous dict."""
    dtype = jnp.dtype(cfg.param_dtype)
    per = cfg.attn_every
    keys = jax.random.split(key, 2 * per)
    p = {}
    for j in range(per):
        km, kf = keys[2 * j], keys[2 * j + 1]
        blk = {"norm": L.rmsnorm_init(cfg.d_model)}
        if _slot_kind(cfg, j) == "attn":
            blk["attn"] = L.attention_init(km, cfg.d_model, cfg.n_heads,
                                           cfg.n_kv_heads, cfg.d_head,
                                           dtype=dtype, bias=cfg.attn_bias)
        else:
            blk["ssm"] = L.mamba2_init(km, cfg.d_model,
                                       d_state=cfg.ssm_state,
                                       n_heads=cfg.ssm_heads,
                                       head_dim=cfg.ssm_head_dim,
                                       conv_kernel=cfg.ssm_conv,
                                       n_groups=cfg.ssm_groups,
                                       expand=cfg.ssm_expand, dtype=dtype)
        blk["ffn_norm"] = L.rmsnorm_init(cfg.d_model)
        if cfg.is_moe_layer(j):
            blk["moe"] = L.moe_init(kf, cfg.d_model, cfg.d_ff,
                                    cfg.n_experts, kind=cfg.ffn_kind,
                                    dtype=dtype)
        else:
            blk["ffn"] = L.ffn_init(kf, cfg.d_model, cfg.d_ff,
                                    kind=cfg.ffn_kind, dtype=dtype)
        p[f"slot{j}"] = blk
    return p


def init(key: jax.Array, cfg: ModelConfig):
    assert cfg.n_layers % cfg.attn_every == 0, \
        "hybrid depth must be a multiple of the period"
    dtype = jnp.dtype(cfg.param_dtype)
    n_periods = cfg.n_layers // cfg.attn_every
    ke, kp, ku = jax.random.split(key, 3)
    pkeys = jax.random.split(kp, n_periods)
    params = {
        "embed": L.embed_init(ke, cfg.vocab_padded, cfg.d_model, dtype=dtype),
        "periods": jax.vmap(partial(period_init, cfg=cfg))(pkeys),
        "final_norm": L.rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.linear_init(ku, cfg.d_model, cfg.vocab_padded,
                                          dtype=dtype, bias=False)
    return params


def _lm_head(params, x, cfg, jcfg):
    if cfg.tie_embeddings:
        return L.unembed_apply(params["embed"], x, jcfg)
    from repro.core.api import head_config
    return L.linear_apply(params["lm_head"], x, head_config(jcfg))


def _slot_apply(blk, x, j, cfg: ModelConfig, jcfg: JigsawConfig, positions,
                aux, state=None, pos=None):
    """One layer inside the period. state: None (train) or the slot's
    cache entry. Returns (x, new_state, aux)."""
    new_state = None
    if _slot_kind(cfg, j) == "attn":
        kv = None if state is None else {"k": state["k"], "v": state["v"],
                                         "pos": pos}
        h = L.rmsnorm_apply(blk["norm"], x)
        out, nc = L.attention_apply(
            blk["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            d_head=cfg.d_head, positions=positions, cfg=jcfg, causal=True,
            window=cfg.sliding_window, rope_theta=cfg.rope_theta,
            kv_cache=kv, rolling=cfg.sliding_window is not None,
            kv_spec=_kv_spec(cfg, jcfg) if kv is not None else None,
            q_chunk=cfg.attn_q_chunk)
        x = x + out
        if nc is not None:
            new_state = {"k": nc["k"], "v": nc["v"]}
    else:
        h = L.rmsnorm_apply(blk["norm"], x)
        out, ns = L.mamba2_apply(
            blk["ssm"], h, d_state=cfg.ssm_state, n_heads=cfg.ssm_heads,
            head_dim=cfg.ssm_head_dim, n_groups=cfg.ssm_groups,
            conv_kernel=cfg.ssm_conv, chunk=cfg.ssm_chunk, cfg=jcfg,
            state=state)
        x = x + out
        new_state = ns
    h = L.rmsnorm_apply(blk["ffn_norm"], x)
    if "moe" in blk:
        # decode (state is not None): never drop tokens (capacity >= T)
        cf = cfg.capacity_factor if state is None else float(cfg.n_experts)
        out, a = L.moe_apply(blk["moe"], h, top_k=cfg.top_k,
                             capacity_factor=cf, cfg=jcfg)
        aux = aux + a
    else:
        out = L.ffn_apply(blk["ffn"], h, jcfg)
    x = x + out
    x = constrain(x, jcfg.rules.act(x.ndim))
    return x, new_state, aux


def apply(params, batch, cfg: ModelConfig,
          jcfg: JigsawConfig = DEFAULT_JIGSAW) -> Tuple[jax.Array, jax.Array]:
    x = L.embed_apply(params["embed"], batch["tokens"])
    b, s, _ = x.shape
    positions = jnp.arange(s)          # 1-D: batch-free attention masks
    x = constrain(x, jcfg.rules.act(x.ndim))

    def body(carry, pp):
        h, aux = carry
        for j in range(cfg.attn_every):
            h, _, aux = _slot_apply(pp[f"slot{j}"], h, j, cfg, jcfg,
                                    positions, aux)
        return (h, aux), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0.0)),
                               params["periods"])
    x = L.rmsnorm_apply(params["final_norm"], x)
    logits = _lm_head(params, x, cfg, jcfg)
    return logits, aux


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               dtype=jnp.bfloat16):
    """Per-slot cache stacked over periods.  Attention slots: KV buffers
    (window-sized if SWA); SSM slots: O(1) conv+state buffers -- which is
    why jamba runs long_500k."""
    n_periods = cfg.n_layers // cfg.attn_every
    w = cfg.sliding_window
    s = min(max_len, w) if w is not None else max_len
    conv_dim = cfg.ssm_d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    slots = {}
    for j in range(cfg.attn_every):
        if _slot_kind(cfg, j) == "attn":
            slots[f"slot{j}"] = {
                "k": jnp.zeros((n_periods, batch_size, s, cfg.n_kv_heads,
                                cfg.d_head), dtype),
                "v": jnp.zeros((n_periods, batch_size, s, cfg.n_kv_heads,
                                cfg.d_head), dtype),
            }
        else:
            slots[f"slot{j}"] = {
                "conv": jnp.zeros((n_periods, batch_size, cfg.ssm_conv - 1,
                                   conv_dim), dtype),
                "ssm": jnp.zeros((n_periods, batch_size, cfg.ssm_heads,
                                  cfg.ssm_head_dim, cfg.ssm_state),
                                 jnp.float32),
            }
    return {"pos": jnp.zeros((batch_size,), jnp.int32), "slots": slots}


def decode_step(params, cache, tokens, cfg: ModelConfig,
                jcfg: JigsawConfig = DEFAULT_JIGSAW):
    x = L.embed_apply(params["embed"], tokens)
    pos = cache["pos"]
    positions = pos[:, None]

    def body(h, xs):
        pp, slot_caches = xs
        new_slots = {}
        for j in range(cfg.attn_every):
            h, ns, _ = _slot_apply(pp[f"slot{j}"], h, j, cfg, jcfg,
                                   positions, jnp.float32(0.0),
                                   state=slot_caches[f"slot{j}"], pos=pos)
            new_slots[f"slot{j}"] = ns
        return h, new_slots

    x, new_slots = jax.lax.scan(body, x, (params["periods"],
                                          cache["slots"]))
    x = L.rmsnorm_apply(params["final_norm"], x)
    logits = _lm_head(params, x, cfg, jcfg)
    return logits, {"pos": pos + 1, "slots": new_slots}
