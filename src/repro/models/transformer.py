"""Generic decoder-only transformer LM.

Covers the dense / MoE / VLM assigned architectures:
  dbrx-132b, internlm2-1.8b, pixtral-12b, gemma3-27b, phi3.5-moe-42b,
  stablelm-3b, h2o-danube-1.8b.

Layers are *stacked* (params carry a leading [L] dim, built by vmapping the
per-layer init) and executed with ``lax.scan`` so the lowered HLO is O(one
layer) regardless of depth -- essential for the 40-pair multi-pod dry-run.
Per-layer heterogeneity (gemma3's 5 local : 1 global attention pattern) is
expressed as a traced per-layer window parameter, so the scan body stays
homogeneous.

All projections are Jigsaw linears (repro.core), so the paper's parallelism
is the default execution mode of every architecture.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.api import DEFAULT_JIGSAW, JigsawConfig
from repro.core.sharding import constrain
from repro.models import layers as L

FULL_WINDOW = jnp.int32(2 ** 30)   # sentinel: no sliding window


def _norm_init(cfg: ModelConfig, d: int):
    return (L.layernorm_init(d) if cfg.norm == "layernorm"
            else L.rmsnorm_init(d))


def _norm_apply(cfg: ModelConfig, p, x):
    return (L.layernorm_apply(p, x) if cfg.norm == "layernorm"
            else L.rmsnorm_apply(p, x))


def layer_init(key: jax.Array, cfg: ModelConfig):
    """One decoder layer's params (no leading dim)."""
    dtype = jnp.dtype(cfg.param_dtype)
    ka, kf = jax.random.split(key)
    p = {
        "attn_norm": _norm_init(cfg, cfg.d_model),
        "attn": L.attention_init(ka, cfg.d_model, cfg.n_heads,
                                 cfg.n_kv_heads, cfg.d_head, dtype=dtype,
                                 bias=cfg.attn_bias),
        "ffn_norm": _norm_init(cfg, cfg.d_model),
    }
    if cfg.qk_norm:
        p["qk_norm"] = {"q": L.rmsnorm_init(cfg.d_head),
                        "k": L.rmsnorm_init(cfg.d_head)}
    if cfg.is_moe_layer(0):   # uniform-MoE archs (dbrx, phi3.5)
        p["moe"] = L.moe_init(kf, cfg.d_model, cfg.d_ff, cfg.n_experts,
                              kind=cfg.ffn_kind, dtype=dtype)
    else:
        p["ffn"] = L.ffn_init(kf, cfg.d_model, cfg.d_ff, kind=cfg.ffn_kind,
                              dtype=dtype)
    return p


def init(key: jax.Array, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kl, ku = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    params = {
        "embed": L.embed_init(ke, cfg.vocab_padded, cfg.d_model, dtype=dtype),
        "layers": jax.vmap(partial(layer_init, cfg=cfg))(layer_keys),
        "final_norm": _norm_init(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.linear_init(ku, cfg.d_model, cfg.vocab_padded,
                                          dtype=dtype, bias=False)
    return params


def layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """Per-layer attention window (traced into the scan body)."""
    ws = [cfg.layer_window(i) for i in range(cfg.n_layers)]
    return jnp.array([w if w is not None else 2 ** 30 for w in ws],
                     jnp.int32)


def _kv_spec(cfg: ModelConfig, jcfg: JigsawConfig):
    """Layer-local cache spec [B, S, Hkv, hd] mirroring specs.cache_specs."""
    from jax.sharding import PartitionSpec as P
    import jax as _jax
    rules = jcfg.rules
    mesh = _jax.sharding.get_abstract_mesh()
    p = mesh.shape.get(rules.tp_axis, 1)
    if p == 1:
        return None
    mode = cfg.kv_shard
    if mode == "auto":
        mode = "heads" if cfg.n_kv_heads % p == 0 else "seq"
    ba = tuple(a for a in rules.batch_axes if a in mesh.shape) or None
    if mode == "heads":
        return P(ba, None, rules.tp_axis, None)
    if mode == "headdim":
        return P(ba, None, None, rules.tp_axis)
    return P(ba, rules.tp_axis, None, None)


def _layer_apply(lp, x, *, cfg: ModelConfig, jcfg: JigsawConfig,
                 positions, window, kv_cache=None, rolling=False,
                 collect_kv=False, aux_in=0.0):
    """One decoder layer. window: traced scalar (2**30 = full causal)."""
    h = _norm_apply(cfg, lp["attn_norm"], x)
    # Traced windows require the mask form (dq - dk < window); sdpa takes
    # window as an array transparently.
    attn_out, new_cache = L.attention_apply(
        lp["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        d_head=cfg.d_head, positions=positions, cfg=jcfg,
        causal=True, window=window, rope_theta=cfg.rope_theta,
        soft_cap=cfg.attn_soft_cap, kv_cache=kv_cache, rolling=rolling,
        collect_kv=collect_kv,
        kv_spec=_kv_spec(cfg, jcfg) if kv_cache is not None else None,
        qk_norm=lp.get("qk_norm"), q_chunk=cfg.attn_q_chunk)
    x = x + attn_out
    h = _norm_apply(cfg, lp["ffn_norm"], x)
    if "moe" in lp:
        # decode: tokens-in-flight is tiny; never drop (capacity >= T)
        cf = cfg.capacity_factor if kv_cache is None else float(cfg.n_experts)
        ffn_out, aux = L.moe_apply(lp["moe"], h, top_k=cfg.top_k,
                                   capacity_factor=cf, cfg=jcfg)
        aux_in = aux_in + aux
    else:
        ffn_out = L.ffn_apply(lp["ffn"], h, jcfg)
    x = x + ffn_out
    x = constrain(x, jcfg.rules.act(x.ndim))
    return x, new_cache, aux_in


def apply(params, batch, cfg: ModelConfig,
          jcfg: JigsawConfig = DEFAULT_JIGSAW) -> Tuple[jax.Array, jax.Array]:
    """Training / prefill forward pass.

    batch: {"tokens": [B, S]} (+ "embeds": [B, P, D] for VLM prefix).
    Returns (logits [B, S_total, vocab_padded], moe_aux_loss scalar).
    """
    tokens = batch["tokens"]
    x = L.embed_apply(params["embed"], tokens)
    if batch.get("embeds") is not None:
        # VLM: vision-frontend stub embeddings are prepended to the text.
        x = jnp.concatenate([batch["embeds"].astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.arange(s)          # 1-D: keeps attention masks [S, S]
    x = constrain(x, jcfg.rules.act(x.ndim))
    windows = layer_windows(cfg)

    def body(carry, xs):
        h, aux = carry
        lp, w = xs
        h, _, aux = _layer_apply(lp, h, cfg=cfg, jcfg=jcfg,
                                 positions=positions, window=w, aux_in=aux)
        return (h, aux), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0.0)),
                               (params["layers"], windows))
    x = _norm_apply(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = L.unembed_apply(params["embed"], x, jcfg)
    else:
        from repro.core.api import head_config
        logits = L.linear_apply(params["lm_head"], x, head_config(jcfg))
    return logits, aux


# ---------------------------------------------------------------------------
# Serving (prefill handled by ``apply``; decode below)
# ---------------------------------------------------------------------------

def _period(cfg: ModelConfig) -> int:
    """Length of the repeating layer pattern (1 for uniform archs)."""
    return cfg.local_global_ratio + 1 if cfg.local_global_ratio > 0 else 1


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               dtype=jnp.bfloat16):
    """KV cache pytree.

    Uniform archs: {"pos", "k", "v"} with k/v [L, B, S, Hkv, hd]; if ALL
    layers share a sliding window, S = min(window, max_len) (rolling) --
    this is what makes long_500k feasible for h2o-danube.

    local:global archs (gemma3): the layer stack is viewed as
    ``n_periods`` repeats of (ratio local + 1 global); local layers get
    window-sized rolling buffers [n_periods, ratio, B, w, ...], global
    layers full-length ones [n_periods, 1, B, S, ...].  Leftover layers
    (depth % period) get their own buffers.  Decode scans over periods so
    layer ORDER is preserved exactly.
    """
    kvshape = lambda nl, s: (nl, batch_size, s, cfg.n_kv_heads, cfg.d_head)
    per = _period(cfg)
    if per == 1:
        w = cfg.sliding_window
        s = min(max_len, w) if w is not None else max_len
        return {"pos": jnp.zeros((batch_size,), jnp.int32),
                "k": jnp.zeros(kvshape(cfg.n_layers, s), dtype),
                "v": jnp.zeros(kvshape(cfg.n_layers, s), dtype)}
    n_per, leftover = divmod(cfg.n_layers, per)
    w = min(cfg.local_window or max_len, max_len)
    ratio = cfg.local_global_ratio
    cache = {
        "pos": jnp.zeros((batch_size,), jnp.int32),
        "lk": jnp.zeros((n_per, ratio) + kvshape(0, w)[1:], dtype),
        "lv": jnp.zeros((n_per, ratio) + kvshape(0, w)[1:], dtype),
        "gk": jnp.zeros((n_per,) + kvshape(0, max_len)[1:], dtype),
        "gv": jnp.zeros((n_per,) + kvshape(0, max_len)[1:], dtype),
    }
    if leftover:  # trailing local layers (gemma3: 62 = 10*6 + 2)
        cache["rk"] = jnp.zeros(kvshape(leftover, w), dtype)
        cache["rv"] = jnp.zeros(kvshape(leftover, w), dtype)
    return cache


def prefill_cache(params, batch, cfg: ModelConfig, jcfg: JigsawConfig,
                  max_len: int, dtype=jnp.bfloat16):
    """Fused prefill: ONE teacher-forced forward over the whole prompt,
    capturing every layer's post-RoPE K/V from the scan and writing them
    back into a fresh decode cache -- O(1) applies instead of O(S)
    decode steps (the ISSUE-8 replacement for the token-wise prefill
    loop, which serve/step.py keeps as the parity reference).

    Returns (logits [B, S, V], cache) positioned exactly as if the
    prompt had been fed token-by-token through ``decode_step``: token p
    lands at slot ``p % s_max`` -- the same rolling slots the token-wise
    writes use -- so decode reads it back with identical absolute-
    position bookkeeping.

    Uniform layer stacks only (``_period == 1``, including all-sliding-
    window rolling caches); local:global stacks (gemma3) raise
    NotImplementedError and the caller falls back token-wise.
    """
    if _period(cfg) != 1:
        raise NotImplementedError("fused prefill: uniform layer stacks "
                                  "only (local:global falls back)")
    if batch.get("embeds") is not None:
        raise NotImplementedError("fused prefill: text prompts only")
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = L.embed_apply(params["embed"], tokens)
    positions = jnp.arange(s)
    x = constrain(x, jcfg.rules.act(x.ndim))
    windows = layer_windows(cfg)

    def body(carry, xs):
        h, aux = carry
        lp, w = xs
        h, kv, aux = _layer_apply(lp, h, cfg=cfg, jcfg=jcfg,
                                  positions=positions, window=w,
                                  collect_kv=True, aux_in=aux)
        return (h, aux), (kv["k"], kv["v"])

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, _aux), (ks, vs) = jax.lax.scan(body_fn, (x, jnp.float32(0.0)),
                                       (params["layers"], windows))
    x = _norm_apply(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = L.unembed_apply(params["embed"], x, jcfg)
    else:
        from repro.core.api import head_config
        logits = L.linear_apply(params["lm_head"], x, head_config(jcfg))

    cache = init_cache(cfg, b, max_len, dtype)
    s_max = cache["k"].shape[2]
    if cfg.sliding_window is None and s > s_max:
        raise ValueError(f"prompt length {s} > cache max_len {s_max}")
    m = min(s, s_max)   # a rolling cache keeps only the last window
    slots = np.arange(s - m, s) % s_max
    ck = cache["k"].at[:, :, slots].set(ks[:, :, s - m:].astype(dtype))
    cv = cache["v"].at[:, :, slots].set(vs[:, :, s - m:].astype(dtype))
    return logits, {"pos": jnp.full((b,), s, jnp.int32), "k": ck, "v": cv}


def decode_step(params, cache, tokens, cfg: ModelConfig,
                jcfg: JigsawConfig = DEFAULT_JIGSAW):
    """One decode step. tokens: [B, 1]. Returns (logits [B, 1, V], cache)."""
    x = L.embed_apply(params["embed"], tokens)
    pos = cache["pos"]
    positions = pos[:, None]
    windows = layer_windows(cfg)
    per = _period(cfg)

    def run_layer(lp, h, w, kc, vc, rolling):
        kv_cache = {"k": kc, "v": vc, "pos": pos}
        h, nc, _ = _layer_apply(lp, h, cfg=cfg, jcfg=jcfg,
                                positions=positions, window=w,
                                kv_cache=kv_cache, rolling=rolling)
        return h, nc["k"], nc["v"]

    if per == 1:
        def body(h, xs):
            lp, w, kc, vc = xs
            h, nk, nv = run_layer(lp, h, w, kc, vc,
                                  rolling=cfg.sliding_window is not None)
            return h, (nk, nv)

        x, (nk, nv) = jax.lax.scan(
            body, x, (params["layers"], windows, cache["k"], cache["v"]))
        new_cache = {"pos": pos + 1, "k": nk, "v": nv}
    else:
        n_per, leftover = divmod(cfg.n_layers, per)
        ratio = cfg.local_global_ratio
        main = jax.tree.map(
            lambda a: a[:n_per * per].reshape((n_per, per) + a.shape[1:]),
            params["layers"])
        w_local = jnp.int32(cfg.local_window)

        def body(h, xs):
            lp, lk, lv, gk, gv = xs
            nlk, nlv = [], []
            for j in range(per):
                lpj = jax.tree.map(lambda a: a[j], lp)
                if j < ratio:   # local layer
                    h, k2, v2 = run_layer(lpj, h, w_local, lk[j], lv[j],
                                          rolling=True)
                    nlk.append(k2); nlv.append(v2)
                else:           # global layer
                    h, gk, gv = run_layer(lpj, h, FULL_WINDOW, gk, gv,
                                          rolling=False)
            return h, (jnp.stack(nlk), jnp.stack(nlv), gk, gv)

        x, (lk, lv, gk, gv) = jax.lax.scan(
            body, x, (main, cache["lk"], cache["lv"], cache["gk"],
                      cache["gv"]))
        new_cache = {"pos": pos + 1, "lk": lk, "lv": lv, "gk": gk, "gv": gv}
        if leftover:
            rest = jax.tree.map(lambda a: a[n_per * per:], params["layers"])

            def body_r(h, xs):
                lp, kc, vc = xs
                h, nk, nv = run_layer(lp, h, w_local, kc, vc, rolling=True)
                return h, (nk, nv)

            x, (rk, rv) = jax.lax.scan(body_r, x,
                                       (rest, cache["rk"], cache["rv"]))
            new_cache["rk"], new_cache["rv"] = rk, rv

    x = _norm_apply(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = L.unembed_apply(params["embed"], x, jcfg)
    else:
        from repro.core.api import head_config
        logits = L.linear_apply(params["lm_head"], x, head_config(jcfg))
    return logits, new_cache
