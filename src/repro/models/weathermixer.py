"""WeatherMixer: the paper's MLP-Mixer atmospheric model (§3).

Encoder (patch conv as reshaped linear, §5) -> N mixing blocks (token-mix
MLP over spatial tokens, channel-mix MLP over latent channels, LayerNorm +
residual around each) -> decoder (un-patch linear) -> learned blend with
the input ("weighted fraction", §3).

Jigsaw integration (the paper's whole point):
  * scheme="2d": activations [B, T, C] sharded (T on mdom, C on mtp).
    Channel mixing contracts C -> ``jigsaw_linear_2d`` (Cannon).  Token
    mixing contracts T *in place* -> ``jigsaw_linear_2d_t`` -- the paper's
    "transposed MLP" trick (§5): no transpose is ever materialized, the
    communication pattern absorbs it.
  * scheme="1d": activations sharded on C only (the paper's 2-way).
    Channel mixing is a 1-D Jigsaw reduce-scatter; token mixing flips the
    sharded dim with an explicit all-to-all-style reshard (the
    "transpose" the paper optimizes; we keep it visible so §Perf can
    compare 1d-with-reshard vs 2d-Cannon).
  * The convolutional encoder/decoder are reshaped linears over
    non-overlapping patches, exactly as in §5.

Rollout fine-tuning (§6): ``apply(..., rollout=r)`` runs the processor r
times with encode/decode once -- the paper's randomized-rollout scheme.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import jigsaw
from repro.core.api import (DEFAULT_JIGSAW, JigsawConfig, linear_apply,
                            linear_init, mlp_apply)
from repro.core.sharding import constrain
from repro.models import layers as L
from jax.sharding import PartitionSpec as P


def n_tokens(cfg: ModelConfig) -> int:
    return (cfg.wm_lat // cfg.wm_patch) * (cfg.wm_lon // cfg.wm_patch)


def patch_dim(cfg: ModelConfig) -> int:
    return cfg.wm_patch * cfg.wm_patch * cfg.wm_channels


def block_init(key: jax.Array, cfg: ModelConfig):
    t, d = n_tokens(cfg), cfg.d_model
    kt1, kt2, kc1, kc2 = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.param_dtype)
    return {
        "tok_norm": L.layernorm_init(d),
        "tok_fc1": linear_init(kt1, t, cfg.wm_d_tok, dtype=dtype),
        "tok_fc2": linear_init(kt2, cfg.wm_d_tok, t, dtype=dtype),
        "ch_norm": L.layernorm_init(d),
        "ch_fc1": linear_init(kc1, d, cfg.wm_d_ch, dtype=dtype),
        "ch_fc2": linear_init(kc2, cfg.wm_d_ch, d, dtype=dtype),
    }


def init(key: jax.Array, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kb, kd, kw = jax.random.split(key, 4)
    bkeys = jax.random.split(kb, cfg.n_layers)
    pd = patch_dim(cfg)
    return {
        "encoder": linear_init(ke, pd, cfg.d_model, dtype=dtype),
        "blocks": jax.vmap(partial(block_init, cfg=cfg))(bkeys),
        "decoder": linear_init(kd, cfg.d_model, pd, dtype=dtype),
        "blend": jnp.zeros((cfg.wm_channels,), jnp.float32),
    }


def patchify(x: jax.Array, p: int) -> jax.Array:
    """[B, lat, lon, C] -> [B, T, p*p*C] over non-overlapping windows."""
    b, lat, lon, c = x.shape
    x = x.reshape(b, lat // p, p, lon // p, p, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, (lat // p) * (lon // p), p * p * c)


def unpatchify(x: jax.Array, lat: int, lon: int, p: int, c: int) -> jax.Array:
    b = x.shape[0]
    x = x.reshape(b, lat // p, lon // p, p, p, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, lat, lon, c)


def _token_mix(bp, x, cfg: ModelConfig, jcfg: JigsawConfig):
    """Token-mixing MLP contracting the token dim of x [B, T, C]."""
    if jcfg.scheme == "2d":
        h = jigsaw.jigsaw_linear_2d_t(x, bp["tok_fc1"]["w"],
                                      bp["tok_fc1"]["b"], rules=jcfg.rules,
                                      accum_dtype=jcfg.accum_dtype,
                                      kernel=jcfg.kernel,
                                      compute_dtype=jcfg.compute_dtype)
        h = jax.nn.gelu(h)
        return jigsaw.jigsaw_linear_2d_t(h, bp["tok_fc2"]["w"],
                                         bp["tok_fc2"]["b"], rules=jcfg.rules,
                                         accum_dtype=jcfg.accum_dtype,
                                         kernel=jcfg.kernel,
                                         compute_dtype=jcfg.compute_dtype)
    # 1d / none: transpose so the contraction is over the last dim; under
    # scheme="1d" the swap flips which dim rides the model axis (an
    # all-to-all in SPMD -- the paper's distributed "transpose").
    xt = jnp.swapaxes(x, -1, -2)                 # [B, C, T]
    if jcfg.scheme == "1d":
        xt = constrain(xt, P(jcfg.rules.batch_axes, None, jcfg.rules.tp_axis))
    # mlp_apply routes through Jigsaw per scheme; under scheme="none" +
    # kernel="pallas" it is the fused two-GEMM ops.mixer_mlp.
    h = mlp_apply({"fc1": bp["tok_fc1"], "fc2": bp["tok_fc2"]}, xt, jcfg)
    return jnp.swapaxes(h, -1, -2)


def _block_apply(bp, x, cfg: ModelConfig, jcfg: JigsawConfig):
    h = L.layernorm_apply(bp["tok_norm"], x)
    x = x + _token_mix(bp, h, cfg, jcfg)
    h = L.layernorm_apply(bp["ch_norm"], x)
    if jcfg.scheme == "2d":
        m = jigsaw.jigsaw_linear_2d(h, bp["ch_fc1"]["w"], bp["ch_fc1"]["b"],
                                    rules=jcfg.rules,
                                    accum_dtype=jcfg.accum_dtype,
                                    kernel=jcfg.kernel,
                                    compute_dtype=jcfg.compute_dtype)
        m = jax.nn.gelu(m)
        m = jigsaw.jigsaw_linear_2d(m, bp["ch_fc2"]["w"], bp["ch_fc2"]["b"],
                                    rules=jcfg.rules,
                                    accum_dtype=jcfg.accum_dtype,
                                    kernel=jcfg.kernel,
                                    compute_dtype=jcfg.compute_dtype)
    else:
        m = mlp_apply({"fc1": bp["ch_fc1"], "fc2": bp["ch_fc2"]}, h, jcfg)
    x = x + m
    if jcfg.scheme != "none":
        x = constrain(x, jcfg.rules.act(x.ndim, domain_dim=-2))
    return x


def processor(params, x, cfg: ModelConfig, jcfg: JigsawConfig,
              rollout: int = 1):
    """The mixing-block stack, applied ``rollout`` times (§6 fine-tuning:
    each pass simulates one 6h step; encode/decode happen once)."""

    def block_body(h, bp):
        return _block_apply(bp, h, cfg, jcfg), None

    body = jax.checkpoint(block_body) if cfg.remat else block_body

    def one_pass(h, _):
        h, _ = jax.lax.scan(body, h, params["blocks"])
        return h, None

    if rollout == 1:
        x, _ = jax.lax.scan(body, x, params["blocks"])
        return x
    x, _ = jax.lax.scan(one_pass, x, None, length=rollout)
    return x


def apply(params, batch, cfg: ModelConfig,
          jcfg: JigsawConfig = DEFAULT_JIGSAW, *, rollout: int = 1
          ) -> Tuple[jax.Array, jax.Array]:
    """batch: {"fields": [B, lat, lon, C]} -> forecast of same shape.

    Returns (forecast, aux=0).  Domain parallelism: under scheme="2d" the
    sample itself is sharded (lon/tokens on mdom, channels/latent on mtp),
    so each model-parallel rank only ever touches its own slice -- the
    paper's partitioned data loading.
    """
    xin = batch["fields"]
    p = cfg.wm_patch
    # block-boundary cast (precision policy): the pipeline ships f32
    # fields; everything from the encoder GEMM to the decoder output --
    # the whole residual stream -- runs in the compute dtype.
    x = L.boundary_cast(patchify(xin, p), jcfg)            # [B, T, p*p*C]
    if jcfg.scheme == "2d":
        x = constrain(x, jcfg.rules.act(3, domain_dim=1))
        h = jigsaw.jigsaw_linear_2d(x, params["encoder"]["w"],
                                    params["encoder"]["b"],
                                    rules=jcfg.rules,
                                    accum_dtype=jcfg.accum_dtype,
                                    kernel=jcfg.kernel,
                                    compute_dtype=jcfg.compute_dtype)
    else:
        h = linear_apply(params["encoder"], x, jcfg)       # [B, T, d]
    h = processor(params, h, cfg, jcfg, rollout=rollout)
    if jcfg.scheme == "2d":
        y = jigsaw.jigsaw_linear_2d(h, params["decoder"]["w"],
                                    params["decoder"]["b"],
                                    rules=jcfg.rules,
                                    accum_dtype=jcfg.accum_dtype,
                                    kernel=jcfg.kernel,
                                    compute_dtype=jcfg.compute_dtype)
    else:
        y = linear_apply(params["decoder"], h, jcfg)       # [B, T, p*p*C]
    y = unpatchify(y, cfg.wm_lat, cfg.wm_lon, p, cfg.wm_channels)
    # learned per-variable blend between persistence (input) and prediction
    # -- the exit boundary: blend in the INPUT dtype (f32) so the loss
    # sees full-precision forecasts even under a bf16 compute policy.
    y = y.astype(xin.dtype)
    lam = jax.nn.sigmoid(params["blend"]).astype(y.dtype)
    out = lam * xin + (1.0 - lam) * y
    return out, jnp.float32(0.0)


def forecast_step(params, fields, cfg: ModelConfig,
                  jcfg: JigsawConfig = DEFAULT_JIGSAW) -> jax.Array:
    """One serving rollout step: the training forward minus every piece
    of loss/grad machinery.  fields [B, lat, lon, C] -> fields at +dt;
    closed over itself it IS the autoregressive forecast (the serving
    engine jits it once per batch bucket and donates ``fields``)."""
    out, _ = apply(params, {"fields": fields}, cfg, jcfg, rollout=1)
    return out
