"""whisper-small: encoder-decoder audio transformer (arXiv:2212.04356).

Per the assignment carve-out, the modality frontend (mel-spectrogram +
two-conv feature extractor) is a STUB: ``input_specs`` provides precomputed
frame embeddings [B, n_frames, d_model].  We implement the transformer
backbone: a bidirectional encoder over frames and a causal decoder with
cross-attention.  Whisper style: LayerNorm, GELU FFN, attention biases,
learned decoder positions, sinusoidal encoder positions, no RoPE.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.api import DEFAULT_JIGSAW, JigsawConfig
from repro.core.sharding import constrain
from repro.models import layers as L


def sinusoids(length: int, channels: int) -> jnp.ndarray:
    """Whisper's sinusoidal positions for the encoder."""
    log_timescale = math.log(10000.0) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2))
    ang = jnp.arange(length)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


def _enc_layer_init(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    ka, kf = jax.random.split(key)
    return {
        "attn_norm": L.layernorm_init(cfg.d_model),
        "attn": L.attention_init(ka, cfg.d_model, cfg.n_heads,
                                 cfg.n_kv_heads, cfg.d_head, dtype=dtype,
                                 bias=True),
        "ffn_norm": L.layernorm_init(cfg.d_model),
        "ffn": L.ffn_init(kf, cfg.d_model, cfg.d_ff, kind="gelu",
                          dtype=dtype, bias=True),
    }


def _dec_layer_init(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    ka, kc, kf = jax.random.split(key, 3)
    p = _enc_layer_init(jax.random.fold_in(key, 7), cfg)
    p["cross_norm"] = L.layernorm_init(cfg.d_model)
    p["cross"] = L.attention_init(kc, cfg.d_model, cfg.n_heads,
                                  cfg.n_kv_heads, cfg.d_head, dtype=dtype,
                                  bias=True)
    return p


def init(key: jax.Array, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kenc, kdec, kpos = jax.random.split(key, 4)
    enc_keys = jax.random.split(kenc, cfg.n_enc_layers)
    dec_keys = jax.random.split(kdec, cfg.n_layers)
    return {
        "embed": L.embed_init(ke, cfg.vocab_padded, cfg.d_model, dtype=dtype),
        "dec_pos": (jax.random.normal(kpos, (4096, cfg.d_model)) * 0.01
                    ).astype(dtype),
        "enc_layers": jax.vmap(partial(_enc_layer_init, cfg=cfg))(enc_keys),
        "enc_norm": L.layernorm_init(cfg.d_model),
        "dec_layers": jax.vmap(partial(_dec_layer_init, cfg=cfg))(dec_keys),
        "dec_norm": L.layernorm_init(cfg.d_model),
    }


def encode(params, frames: jax.Array, cfg: ModelConfig,
           jcfg: JigsawConfig = DEFAULT_JIGSAW) -> jax.Array:
    """frames: [B, n_frames, d_model] stub embeddings -> encoder states."""
    b, s, d = frames.shape
    x = frames + sinusoids(s, d)[None].astype(frames.dtype)
    positions = jnp.arange(s)
    x = constrain(x, jcfg.rules.act(x.ndim))

    def body(h, lp):
        a = L.layernorm_apply(lp["attn_norm"], h)
        out, _ = L.attention_apply(
            lp["attn"], a, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            d_head=cfg.d_head, positions=positions, cfg=jcfg, causal=False,
            rope_theta=None, q_chunk=cfg.attn_q_chunk)
        h = h + out
        f = L.layernorm_apply(lp["ffn_norm"], h)
        h = h + L.ffn_apply(lp["ffn"], f, jcfg)
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
    return L.layernorm_apply(params["enc_norm"], x)


def _dec_layer(lp, x, enc, cfg, jcfg, positions, kv_cache=None, pos=None):
    a = L.layernorm_apply(lp["attn_norm"], x)
    out, nc = L.attention_apply(
        lp["attn"], a, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        d_head=cfg.d_head, positions=positions, cfg=jcfg, causal=True,
        rope_theta=None, kv_cache=kv_cache,
        q_chunk=0 if kv_cache is not None else cfg.attn_q_chunk)
    x = x + out
    c = L.layernorm_apply(lp["cross_norm"], x)
    out, _ = L.attention_apply(
        lp["cross"], c, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        d_head=cfg.d_head, positions=positions, cfg=jcfg, causal=False,
        rope_theta=None, x_kv=enc,
        q_chunk=0 if positions.ndim > 1 else cfg.attn_q_chunk)
    x = x + out
    f = L.layernorm_apply(lp["ffn_norm"], x)
    x = x + L.ffn_apply(lp["ffn"], f, jcfg)
    return x, nc


def apply(params, batch, cfg: ModelConfig,
          jcfg: JigsawConfig = DEFAULT_JIGSAW) -> Tuple[jax.Array, jax.Array]:
    """batch: {"frames": [B, F, D] (stub), "tokens": [B, S]}."""
    enc = encode(params, batch["frames"], cfg, jcfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = L.embed_apply(params["embed"], tokens)
    # wrap beyond the learned table (whisper's real ceiling is 448 tokens;
    # the 32k assigned shapes are exercised purely as lowering shapes)
    plen = params["dec_pos"].shape[0]
    x = x + jnp.take(params["dec_pos"], jnp.arange(s) % plen,
                     axis=0)[None].astype(x.dtype)
    positions = jnp.arange(s)
    x = constrain(x, jcfg.rules.act(x.ndim))

    def body(h, lp):
        h, _ = _dec_layer(lp, h, enc, cfg, jcfg, positions)
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_layers"])
    x = L.layernorm_apply(params["dec_norm"], x)
    logits = L.unembed_apply(params["embed"], x, jcfg)
    return logits, jnp.float32(0.0)


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               dtype=jnp.bfloat16):
    return {
        "pos": jnp.zeros((batch_size,), jnp.int32),
        "k": jnp.zeros((cfg.n_layers, batch_size, max_len, cfg.n_kv_heads,
                        cfg.d_head), dtype),
        "v": jnp.zeros((cfg.n_layers, batch_size, max_len, cfg.n_kv_heads,
                        cfg.d_head), dtype),
        # encoder states computed once at prefill, reused every step
        "enc": jnp.zeros((batch_size, cfg.n_frames, cfg.d_model), dtype),
    }


def decode_step(params, cache, tokens, cfg: ModelConfig,
                jcfg: JigsawConfig = DEFAULT_JIGSAW):
    pos = cache["pos"]
    x = L.embed_apply(params["embed"], tokens)
    x = x + jnp.take(params["dec_pos"],
                     pos % params["dec_pos"].shape[0],
                     axis=0)[:, None, :].astype(x.dtype)
    positions = pos[:, None]
    enc = cache["enc"].astype(x.dtype)

    def body(h, xs):
        lp, kc, vc = xs
        h, nc = _dec_layer(lp, h, enc, cfg, jcfg, positions,
                           kv_cache={"k": kc, "v": vc, "pos": pos}, pos=pos)
        return h, (nc["k"], nc["v"])

    x, (nk, nv) = jax.lax.scan(body, x, (params["dec_layers"], cache["k"],
                                         cache["v"]))
    x = L.layernorm_apply(params["dec_norm"], x)
    logits = L.unembed_apply(params["embed"], x, jcfg)
    return logits, {"pos": pos + 1, "k": nk, "v": nv, "enc": cache["enc"]}
