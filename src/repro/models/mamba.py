"""mamba2-130m: attention-free SSM LM (SSD, arXiv:2405.21060).

§Arch-applicability (DESIGN.md): Jigsaw applies to the in/out projections
(the bulk of the FLOPs); the SSD scan itself is a recurrence, not a
matmul, so it is sharded over SSM heads on the model axis rather than over
the sequence (domain) -- a documented deviation forced by causality.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.api import DEFAULT_JIGSAW, JigsawConfig
from repro.core.sharding import constrain
from repro.models import layers as L


def layer_init(key: jax.Array, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    return {
        "norm": L.rmsnorm_init(cfg.d_model),
        "mixer": L.mamba2_init(key, cfg.d_model, d_state=cfg.ssm_state,
                               n_heads=cfg.ssm_heads,
                               head_dim=cfg.ssm_head_dim,
                               conv_kernel=cfg.ssm_conv,
                               n_groups=cfg.ssm_groups,
                               expand=cfg.ssm_expand, dtype=dtype),
    }


def init(key: jax.Array, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kl = jax.random.split(key)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    return {
        "embed": L.embed_init(ke, cfg.vocab_padded, cfg.d_model, dtype=dtype),
        "layers": jax.vmap(partial(layer_init, cfg=cfg))(layer_keys),
        "final_norm": L.rmsnorm_init(cfg.d_model),
    }


def _mixer(lp, x, cfg: ModelConfig, jcfg: JigsawConfig, state=None):
    h = L.rmsnorm_apply(lp["norm"], x)
    out, new_state = L.mamba2_apply(
        lp["mixer"], h, d_state=cfg.ssm_state, n_heads=cfg.ssm_heads,
        head_dim=cfg.ssm_head_dim, n_groups=cfg.ssm_groups,
        conv_kernel=cfg.ssm_conv, chunk=cfg.ssm_chunk, cfg=jcfg,
        state=state)
    return x + out, new_state


def apply(params, batch, cfg: ModelConfig,
          jcfg: JigsawConfig = DEFAULT_JIGSAW) -> Tuple[jax.Array, jax.Array]:
    x = L.embed_apply(params["embed"], batch["tokens"])
    x = constrain(x, jcfg.rules.act(x.ndim))

    def body(h, lp):
        h, _ = _mixer(lp, h, cfg, jcfg)
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["layers"])
    x = L.rmsnorm_apply(params["final_norm"], x)
    logits = L.unembed_apply(params["embed"], x, jcfg)
    return logits, jnp.float32(0.0)


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               dtype=jnp.bfloat16):
    """SSM state is O(1) in sequence length -- the whole point of running
    long_500k on this family."""
    del max_len
    conv_dim = cfg.ssm_d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "pos": jnp.zeros((batch_size,), jnp.int32),
        "conv": jnp.zeros((cfg.n_layers, batch_size, cfg.ssm_conv - 1,
                           conv_dim), dtype),
        "ssm": jnp.zeros((cfg.n_layers, batch_size, cfg.ssm_heads,
                          cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
    }


def decode_step(params, cache, tokens, cfg: ModelConfig,
                jcfg: JigsawConfig = DEFAULT_JIGSAW):
    x = L.embed_apply(params["embed"], tokens)

    def body(h, xs):
        lp, conv, ssm = xs
        h, ns = _mixer(lp, h, cfg, jcfg, state={"conv": conv, "ssm": ssm})
        return h, (ns["conv"], ns["ssm"])

    x, (conv, ssm) = jax.lax.scan(
        body, x, (params["layers"], cache["conv"], cache["ssm"]))
    x = L.rmsnorm_apply(params["final_norm"], x)
    logits = L.unembed_apply(params["embed"], x, jcfg)
    return logits, {"pos": cache["pos"] + 1, "conv": conv, "ssm": ssm}
