"""mamba2-130m: attention-free SSM LM, SSD [arXiv:2405.21060].

24L d_model=768, ssm_state=128, vocab=50280 (padded to 50432 for 16-way
sharding).  O(1) decode state -> runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_heads=24, ssm_head_dim=64, ssm_groups=1,
    ssm_conv=4, ssm_chunk=64, ssm_expand=2,
    rope_theta=None, tie_embeddings=True,
    supports_long_context=True,
    source="arXiv:2405.21060",
)
