"""Architecture configuration system.

Every assigned architecture gets a ``src/repro/configs/<id>.py`` exporting
``CONFIG`` (exact published dimensions, source cited) built on this
dataclass.  ``reduced()`` derives the CPU smoke-test variant (<=2 layers,
d_model<=512, <=4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.sharding import pad_to_multiple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                    # dense|moe|hybrid|ssm|vlm|audio|mixer
    n_layers: int
    d_model: int
    # --- attention ---
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0                # 0 -> d_model // n_heads
    rope_theta: Optional[float] = 10000.0
    attn_bias: bool = False
    attn_soft_cap: Optional[float] = None
    qk_norm: bool = False
    sliding_window: Optional[int] = None   # all layers (SWA archs)
    attn_q_chunk: int = 0                  # >0: chunked online-softmax attn
    kv_shard: str = "auto"                 # decode cache: auto|heads|seq|headdim
    local_window: Optional[int] = None     # local layers (local:global)
    local_global_ratio: int = 0            # N local : 1 global; 0 = off
    # --- ffn ---
    d_ff: int = 0
    ffn_kind: str = "swiglu"               # swiglu|gelu
    # --- vocab / embeddings ---
    vocab_size: int = 0
    tie_embeddings: bool = True
    norm: str = "rmsnorm"                  # rmsnorm|layernorm
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_every: int = 1                     # layer i is MoE iff i % moe_every
    moe_offset: int = 0                    #   == moe_offset (when n_experts)
    # --- SSM (mamba2 / hybrid) ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 64
    ssm_expand: int = 2
    attn_every: int = 0                    # hybrid: 1 attn layer per this
    attn_offset: int = 0
    # --- enc-dec / frontends (stubs provide the embeddings) ---
    enc_dec: bool = False
    n_enc_layers: int = 0
    n_frames: int = 1500                   # audio frontend stub output len
    n_patches: int = 1024                  # vision frontend stub output len
    # --- WeatherMixer ---
    wm_lat: int = 0
    wm_lon: int = 0
    wm_channels: int = 0
    wm_patch: int = 0
    wm_d_tok: int = 0                      # token-mixing hidden dim
    wm_d_ch: int = 0                       # channel-mixing hidden dim
    # --- numerics ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    precision: Optional[str] = None        # policy preset (core/precision):
                                           # fp32|bf16|bf16_pure; None =
                                           # legacy dtypes above, fp32 accum
    # --- parallelism defaults (overridable from the launcher) ---
    scheme: str = "1d"                     # jigsaw scheme: 1d|2d|none
    impl: str = "rs"                       # 1d impl: ring|ring_chunked|
                                           #   ring_fused|rs|gspmd|allreduce
    kernel: str = "xla"                    # local GEMM engine: xla|pallas
    shard_params_over_data: bool = False   # FSDP-hybrid for >~25B params
    remat: bool = True
    # --- capability flags ---
    supports_decode: bool = True
    supports_long_context: bool = False    # sub-quadratic decode at 500k
    source: str = ""                       # citation

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.n_heads and not self.d_head:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up so the LM head shards evenly 16-way."""
        return pad_to_multiple(self.vocab_size, 256) if self.vocab_size else 0

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_heads * self.ssm_head_dim

    def is_moe_layer(self, i: int) -> bool:
        return (self.n_experts > 0 and self.moe_every > 0
                and i % self.moe_every == self.moe_offset)

    def is_attn_layer(self, i: int) -> bool:
        """Hybrid archs: which layers are attention (vs SSM)."""
        if self.attn_every <= 0:
            return True
        return i % self.attn_every == self.attn_offset

    def layer_window(self, i: int) -> Optional[int]:
        """Attention window for layer i (None = full causal)."""
        if self.sliding_window is not None:
            return self.sliding_window
        if self.local_global_ratio > 0:
            # pattern: ratio local layers, then 1 global
            if i % (self.local_global_ratio + 1) != self.local_global_ratio:
                return self.local_window
        return None

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family/topology, tiny dims."""
        kw = dict(
            n_layers=2, d_model=min(self.d_model, 256),
            param_dtype="float32", compute_dtype="float32", precision=None,
            scheme="none", remat=False, shard_params_over_data=False,
            # pallas on CPU is interpret-mode (slow): smoke tests opt in
            # explicitly instead of inheriting the production default;
            # impl resets with scheme (a 1-D impl under scheme="none"
            # would trip the JigsawConfig ignored-impl warning)
            kernel="xla", impl="rs",
        )
        if self.n_heads:
            kw["n_heads"] = min(self.n_heads, 4)
            kw["n_kv_heads"] = min(self.n_kv_heads or self.n_heads, 2)
            kw["d_head"] = kw["d_model"] // kw["n_heads"]
        if self.d_ff:
            kw["d_ff"] = min(self.d_ff, 512)
        if self.vocab_size:
            kw["vocab_size"] = min(self.vocab_size, 1024)
        if self.n_experts:
            kw["n_experts"] = min(self.n_experts, 4)
            kw["top_k"] = min(self.top_k, 2)
        if self.ssm_heads:
            kw["ssm_heads"] = 8
            kw["ssm_head_dim"] = (kw["d_model"] * self.ssm_expand) // 8
            kw["ssm_state"] = min(self.ssm_state, 32)
        if self.attn_every:
            kw["attn_every"] = 2
            kw["attn_offset"] = min(self.attn_offset, 1)
        if self.moe_every > 1:
            kw["moe_every"] = 2
        if self.n_enc_layers:
            kw["n_enc_layers"] = 2
        if self.enc_dec:
            kw["n_frames"] = 64
        if self.family == "vlm":
            kw["n_patches"] = 16
        if self.sliding_window:
            kw["sliding_window"] = 64
        if self.local_window:
            kw["local_window"] = 32
        if self.wm_lat:
            kw.update(wm_lat=32, wm_lon=64, wm_channels=8, wm_patch=4,
                      wm_d_tok=128, wm_d_ch=128, d_model=128)
        return self.replace(**kw)

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D and the
        zero-redundancy memory checks)."""
        n = 0
        D = self.d_model
        if self.family == "mixer":
            t = (self.wm_lat // self.wm_patch) * (self.wm_lon // self.wm_patch)
            pin = self.wm_patch * self.wm_patch * self.wm_channels
            n += pin * D + D  # encoder
            per = (t * self.wm_d_tok * 2 + self.wm_d_tok + t            # token MLP
                   + D * self.wm_d_ch * 2 + self.wm_d_ch + D            # channel MLP
                   + 4 * D)                                             # norms
            n += self.n_layers * per
            n += D * pin + pin  # decoder
            n += 2  # blend
            return n
        V = self.vocab_padded
        n += V * D
        if not self.tie_embeddings:
            n += V * D
        hd = self.d_head
        attn = D * self.n_heads * hd + 2 * D * (self.n_kv_heads * hd) \
            + self.n_heads * hd * D if self.n_heads else 0
        ffn_dense = (3 if self.ffn_kind == "swiglu" else 2) * D * self.d_ff
        ffn_moe = self.n_experts * ffn_dense + self.n_experts * D
        ssm = 0
        if self.ssm_heads:
            din = self.ssm_d_inner
            dinp = 2 * din + 2 * self.ssm_groups * self.ssm_state + self.ssm_heads
            ssm = D * dinp + din * D \
                + self.ssm_conv * (din + 2 * self.ssm_groups * self.ssm_state) \
                + 3 * self.ssm_heads + din
        for i in range(self.n_layers):
            if self.family == "ssm":
                n += ssm + D
                continue
            if self.is_attn_layer(i):
                n += attn + D
            else:
                n += ssm + D
            if self.is_moe_layer(i):
                n += ffn_moe + D
            elif self.d_ff:
                n += ffn_dense + D
        n += D  # final norm
        if self.enc_dec:
            enc_per = attn + ffn_dense + 3 * D
            dec_cross = attn + D
            n += self.n_enc_layers * enc_per + self.n_layers * dec_cross
            n += 4096 * D  # learned decoder position table
        return n
