"""WeatherMixer: the paper's own architecture (§3, §6.2).

The 1-billion-parameter configuration from §6.2.1: 3 MLP-Mixing blocks,
d_emb = 4320, d_tok = 8640, d_ch = 4320, on 0.25-degree ERA5
(721x1440 grid, padded to 728x1440 for 8x8 patching; 69 variables:
4 surface + 5x13 pressure levels).  Table 1 gives the scaling zoo; see
``weathermixer_zoo`` below (used by the scaling benchmarks).
"""
from repro.configs.base import ModelConfig

def _wm(name, d_emb, d_tok, d_ch, n_layers=3, lat=728, lon=1440, chans=69,
        patch=8):
    return ModelConfig(
        arch_id=name, family="mixer",
        n_layers=n_layers, d_model=d_emb,
        wm_lat=lat, wm_lon=lon, wm_channels=chans, wm_patch=patch,
        wm_d_tok=d_tok, wm_d_ch=d_ch,
        norm="layernorm", scheme="2d",
        # production compute engine: MXU-tiled Pallas GEMMs; when launched
        # with scheme="1d" the ring runs the paper's chunked overlap
        # schedule (DESIGN.md §8).  reduced() resets both for CPU smoke.
        kernel="pallas", impl="ring_chunked",
        supports_decode=False, supports_long_context=False,
        source="Kieckhefen et al. 2025 (the reproduced paper), §6.2/Table 1",
    )

CONFIG = _wm("weathermixer-1b", 4320, 8640, 4320)

# Table 1 scaling zoo (TFLOPs/forward pass -> dims), models 1-9.
ZOO = {
    1: _wm("wm-zoo-0.25t", 240, 540, 240),
    2: _wm("wm-zoo-0.5t", 512, 2160, 512),
    3: _wm("wm-zoo-1t", 896, 2160, 896),
    4: _wm("wm-zoo-2t", 1600, 2160, 1600),
    5: _wm("wm-zoo-4t", 2192, 4320, 2192),
    6: _wm("wm-zoo-8t", 2832, 8640, 2832),
    7: _wm("wm-zoo-16t", 4896, 8640, 4896),
    8: _wm("wm-zoo-32t", 6064, 17280, 6064),
    9: _wm("wm-zoo-64t", 10352, 17280, 10352),
}
