"""Config registry: ``get_config(arch_id)`` for every assigned arch."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig

ARCH_IDS: List[str] = [
    "dbrx-132b",
    "jamba-1.5-large-398b",
    "internlm2-1.8b",
    "pixtral-12b",
    "gemma3-27b",
    "phi3.5-moe-42b-a6.6b",
    "whisper-small",
    "stablelm-3b",
    "mamba2-130m",
    "h2o-danube-1.8b",
    "weathermixer-1b",
]

_MODULE_FOR = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
               for a in ARCH_IDS}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULE_FOR:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(_MODULE_FOR[arch_id])
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
