"""internlm2-1.8b: dense GQA decoder [arXiv:2403.17297].

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.
Full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internlm2-1.8b", family="dense",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab_size=92544, ffn_kind="swiglu",
    rope_theta=1000000.0, tie_embeddings=True,
    supports_long_context=False,
    source="arXiv:2403.17297",
)
