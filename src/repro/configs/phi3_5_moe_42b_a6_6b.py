"""phi3.5-moe-42b-a6.6b: MoE decoder [hf:microsoft/Phi-3.5-MoE-instruct].

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, 16 experts top-2.
Full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=6400, vocab_size=32064,
    n_experts=16, top_k=2, ffn_kind="swiglu",
    rope_theta=10000.0, tie_embeddings=False,
    shard_params_over_data=True,          # 42B
    supports_long_context=False,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
