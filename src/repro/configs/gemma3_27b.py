"""gemma3-27b: dense decoder, 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt family].

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144.  Local layers
use a 1024-token sliding window -> rolling caches make long_500k decode
feasible (only the 1-in-6 global layers hold full-length caches).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, d_head=128,
    d_ff=21504, vocab_size=262144, ffn_kind="gelu",
    local_window=1024, local_global_ratio=5,
    rope_theta=1000000.0, qk_norm=True, tie_embeddings=True,
    shard_params_over_data=True,          # 27B + 262k-vocab embeddings
    supports_long_context=True,
    source="hf:google/gemma-3-1b-pt",
)
