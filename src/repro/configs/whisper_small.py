"""whisper-small: encoder-decoder audio transformer [arXiv:2212.04356].

12L (decoder; +12 encoder) d_model=768 12H (kv=12) d_ff=3072 vocab=51865.
The mel+conv frontend is a STUB: input_specs() provides frame embeddings
[B, 1500, 768].  Enc-dec full attention -> long_500k skipped; decode_32k
is exercised purely as a lowering shape (whisper's real decoder max is
448 tokens).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=51865, ffn_kind="gelu",
    norm="layernorm", attn_bias=True, rope_theta=None,
    enc_dec=True, n_enc_layers=12, n_frames=1500,
    tie_embeddings=True,
    supports_long_context=False,
    source="arXiv:2212.04356",
)
