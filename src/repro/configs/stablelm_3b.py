"""stablelm-3b: dense decoder [hf:stabilityai/stablelm-2-1_6b family].

32L d_model=2560 32H (GQA kv=32 = MHA) d_ff=6912 vocab=50304.
Full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=6912, vocab_size=50304, ffn_kind="swiglu",
    rope_theta=10000.0, tie_embeddings=True,
    supports_long_context=False,
    source="hf:stabilityai/stablelm-2-1_6b",
)
