"""dbrx-132b: fine-grained MoE decoder [hf:databricks/dbrx-base].

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, MoE 16 experts
top-4.  Full attention -> long_500k skipped (DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab_size=100352,
    n_experts=16, top_k=4, ffn_kind="swiglu",
    rope_theta=500000.0, tie_embeddings=False,
    shard_params_over_data=True,          # 132B: params exceed 16-way HBM
    supports_long_context=False,
    source="hf:databricks/dbrx-base",
)
