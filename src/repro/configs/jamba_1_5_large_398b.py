"""jamba-1.5-large-398b: hybrid Mamba+attention 1:7 with MoE
[arXiv:2403.19887].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Period of 8 layers: 1 attention + 7 SSM; MoE every other layer.  SSM
state is O(1) -> runs long_500k.
"""
from repro.configs.base import ModelConfig

D = 8192
CONFIG = ModelConfig(
    arch_id="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=D, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab_size=65536,
    n_experts=16, top_k=2, moe_every=2, moe_offset=1,
    attn_every=8, attn_offset=4,          # jamba puts attn mid-period
    ssm_state=128, ssm_heads=2 * D // 64, ssm_head_dim=64, ssm_groups=8,
    rope_theta=None,                      # jamba uses no positional enc.
    tie_embeddings=False,
    shard_params_over_data=True,          # 398B
    supports_long_context=True,
    source="arXiv:2403.19887",
)
