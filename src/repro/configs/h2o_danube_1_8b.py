"""h2o-danube-1.8b: llama+mistral-style dense decoder with sliding-window
attention [arXiv:2401.16818].

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, SWA window 4096.
Window caches are O(window) -> runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=6912, vocab_size=32000, ffn_kind="swiglu",
    sliding_window=4096,
    rope_theta=10000.0, tie_embeddings=False,
    supports_long_context=True,
    source="arXiv:2401.16818",
)
