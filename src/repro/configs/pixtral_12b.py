"""pixtral-12b: VLM -- mistral-nemo decoder consuming pixtral-ViT patch
embeddings [hf:mistralai/Pixtral-12B-2409].

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.  The vision
frontend is a STUB per the assignment carve-out: input_specs() provides
precomputed patch embeddings [B, n_patches, d_model].
Full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab_size=131072, ffn_kind="swiglu",
    rope_theta=1000000000.0, tie_embeddings=False,
    n_patches=1024,
    supports_long_context=False,
    source="hf:mistralai/Pixtral-12B-2409",
)
