"""jit'd public wrappers around the Pallas kernels (padding + reshapes +
custom VJPs).

``matmul`` is the MXU-tiled GEMM used as Jigsaw's compute engine
(``JigsawConfig(kernel="pallas")``): f32 VMEM accumulation, bias + GELU /
SiLU epilogue fused into the final K-step.  Block sizes shrink toward the
problem size (keeping the sublane/lane alignment floors) so a 16-row GEMM
does not pad to a 256-row tile.  A custom VJP makes the path trainable:
the backward GEMMs (dx = dz @ w, dw = dz^T @ x) are themselves routed
through the same Pallas kernel, and fused epilogues recompute their
pre-activation with one extra kernel call (flash-attention-style
recomputation) instead of saving it.

``mixer_mlp`` is the drop-in fused path for the WeatherMixer mixing MLPs:
two MXU-tiled GEMMs with the GELU fused into the first's epilogue.  The
wrappers pad every dim up to the block grid and slice the result back.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.block_matmul import block_matmul, sublane as _sublane

_ACTS = {"gelu": jax.nn.gelu, "silu": jax.nn.silu}


def _pad_to(a: jax.Array, dim: int, mult: int) -> jax.Array:
    rem = a.shape[dim] % mult
    if rem == 0:
        return a
    pad = [(0, 0)] * a.ndim
    pad[dim] = (0, mult - rem)
    return jnp.pad(a, pad)


def _round_up(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def block_dims(m: int, n: int, k: int, *, block_m: int, block_n: int,
               block_k: int, dtype=jnp.float32):
    """Shrink the requested block sizes toward the problem size.

    m shrinks to its sublane-aligned ceiling, n and k to their lane (128)
    ceilings, so small GEMMs run a single right-sized block instead of
    padding up to the full default tile (a 16-row f32 GEMM runs a 16-row
    block, not a 256-row one).
    """
    bm = min(block_m, _round_up(m, _sublane(dtype)))
    bn = min(block_n, _round_up(n, 128))
    bk = min(block_k, _round_up(k, 128))
    return bm, bn, bk


def _matmul_raw(x, w, b, epilogue, block_m, block_n, block_k, interpret):
    """Pad/shrink to the block grid, run the kernel, slice back.

    bf16 inputs run the MXU at its half-width rate with fp32 VMEM
    accumulation inside the kernel; ``block_dims`` widens the sublane
    floor to 16 rows for 2-byte dtypes (the TPU tile constraint) so a
    bf16 GEMM never issues an 8-row tile the hardware cannot form.
    """
    if w.dtype != x.dtype:
        # policy casts happen at the linear-apply boundary; anything that
        # still arrives mixed (e.g. an fp32 cotangent against bf16
        # residuals) is unified to x's dtype -- the MXU needs one operand
        # width and the f32 scratch keeps the accumulation exact either way
        w = w.astype(x.dtype)
    m, k = x.shape
    n = w.shape[0]
    bm, bn, bk = block_dims(m, n, k, block_m=block_m, block_n=block_n,
                            block_k=block_k, dtype=x.dtype)
    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w, 0, bn), 1, bk)
    bp = _pad_to(b, 0, bn) if b is not None else None
    y = block_matmul(xp, wp, bp, block_m=bm, block_n=bn, block_k=bk,
                     epilogue=epilogue, interpret=interpret)
    return y[:m, :n]


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _matmul(x, w, b, epilogue, block_m, block_n, block_k, interpret):
    return _matmul_raw(x, w, b, epilogue, block_m, block_n, block_k,
                       interpret)


def _matmul_fwd(x, w, b, epilogue, block_m, block_n, block_k, interpret):
    y = _matmul_raw(x, w, b, epilogue, block_m, block_n, block_k, interpret)
    return y, (x, w, b)


def _matmul_bwd(epilogue, block_m, block_n, block_k, interpret, res, dy):
    x, w, b = res
    blk = (block_m, block_n, block_k, interpret)
    if epilogue == "none":
        dz = dy
    else:
        # Recompute the pre-activation z = x @ w.T + b with one more
        # kernel call (cheaper than saving the [M, N] f32 accumulator).
        z = _matmul_raw(x, w, b, "none", *blk).astype(jnp.float32)
        _, act_vjp = jax.vjp(_ACTS[epilogue], z)
        dz = act_vjp(dy.astype(jnp.float32))[0].astype(dy.dtype)
    # Backward GEMMs through the same MXU-tiled kernel:
    #   dx[m, k] = dz @ w   and   dw[n, k] = dz^T @ x.
    dx = _matmul_raw(dz, w.T, None, "none", *blk).astype(x.dtype)
    dw = _matmul_raw(dz.T, x.T, None, "none", *blk).astype(w.dtype)
    db = jnp.sum(dz, axis=0).astype(b.dtype) if b is not None else None
    return dx, dw, db


_matmul.defvjp(_matmul_fwd, _matmul_bwd)


@partial(jax.jit, static_argnames=("epilogue", "block_m", "block_n",
                                   "block_k", "interpret"))
def matmul(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None, *,
           epilogue: str = "none", block_m: int = 256, block_n: int = 256,
           block_k: int = 512, interpret: bool = None) -> jax.Array:
    """Padded/blocked y = epilogue(x @ w.T + b) for arbitrary 2-D shapes.

    Differentiable (custom VJP; backward GEMMs also run the Pallas
    kernel), so it can sit inside the distributed training hot path.
    """
    return _matmul(x, w, b, epilogue, block_m, block_n, block_k, interpret)


def matmul_nd(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
              **kw) -> jax.Array:
    """``matmul`` over the last dim of an arbitrary-rank x [..., d_in]."""
    lead = x.shape[:-1]
    y = matmul(x.reshape(-1, x.shape[-1]), w, b, **kw)
    return y.reshape(lead + (w.shape[0],))


@partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                   "interpret"))
def mixer_mlp(x: jax.Array, w1: jax.Array, b1: Optional[jax.Array],
              w2: jax.Array, b2: Optional[jax.Array], *,
              block_m: int = 256, block_n: int = 256,
              block_k: int = 512, interpret: bool = None) -> jax.Array:
    """Fused mixer MLP over the last dim: gelu(x @ w1.T + b1) @ w2.T + b2.

    x: [..., rows, d_in]; w1: [d_h, d_in]; w2: [d_out, d_h].
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    h = matmul(x2, w1, b1, epilogue="gelu", block_m=block_m,
               block_n=block_n, block_k=block_k, interpret=interpret)
    y = matmul(h, w2, b2, epilogue="none", block_m=block_m,
               block_n=block_n, block_k=block_k, interpret=interpret)
    return y.reshape(lead + (w2.shape[0],))


@partial(jax.jit, static_argnames=("interpret",))
def ssd_intra(c, b, x, dt, dac, *, interpret=None):
    """Fused intra-chunk SSD (see kernels/ssd_chunk.py).  Accepts the
    mamba2 layout [B, nc, Q, H, ...] and flattens to the kernel grid."""
    from repro.kernels.ssd_chunk import ssd_intra_chunk
    return ssd_intra_chunk(c, b, x, dt, dac, interpret=interpret)
