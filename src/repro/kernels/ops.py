"""jit'd public wrappers around the Pallas kernels (padding + reshapes).

``mixer_mlp`` is the drop-in fused path for the WeatherMixer mixing MLPs:
two MXU-tiled GEMMs with the GELU fused into the first's epilogue.  The
wrapper pads every dim up to the block grid and slices the result back.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.block_matmul import block_matmul


def _pad_to(a: jax.Array, dim: int, mult: int) -> jax.Array:
    rem = a.shape[dim] % mult
    if rem == 0:
        return a
    pad = [(0, 0)] * a.ndim
    pad[dim] = (0, mult - rem)
    return jnp.pad(a, pad)


@partial(jax.jit, static_argnames=("epilogue", "block_m", "block_n",
                                   "block_k", "interpret"))
def matmul(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None, *,
           epilogue: str = "none", block_m: int = 256, block_n: int = 256,
           block_k: int = 512, interpret: bool = None) -> jax.Array:
    """Padded/blocked y = epilogue(x @ w.T + b) for arbitrary 2-D shapes."""
    m, k = x.shape
    n = w.shape[0]
    bm = min(block_m, max(8, m))
    xp = _pad_to(_pad_to(x, 0, block_m), 1, block_k)
    wp = _pad_to(_pad_to(w, 0, block_n), 1, block_k)
    bp = _pad_to(b, 0, block_n) if b is not None else None
    y = block_matmul(xp, wp, bp, block_m=block_m, block_n=block_n,
                     block_k=block_k, epilogue=epilogue,
                     interpret=interpret)
    return y[:m, :n]


@partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                   "interpret"))
def mixer_mlp(x: jax.Array, w1: jax.Array, b1: jax.Array, w2: jax.Array,
              b2: jax.Array, *, block_m: int = 256, block_n: int = 256,
              block_k: int = 512, interpret: bool = None) -> jax.Array:
    """Fused mixer MLP over the last dim: gelu(x @ w1.T + b1) @ w2.T + b2.

    x: [..., rows, d_in]; w1: [d_h, d_in]; w2: [d_out, d_h].
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    h = matmul(x2, w1, b1, epilogue="gelu", block_m=block_m,
               block_n=block_n, block_k=block_k, interpret=interpret)
    y = matmul(h, w2, b2, epilogue="none", block_m=block_m,
               block_n=block_n, block_k=block_k, interpret=interpret)
    return y.reshape(lead + (w2.shape[0],))


@partial(jax.jit, static_argnames=("interpret",))
def ssd_intra(c, b, x, dt, dac, *, interpret=None):
    """Fused intra-chunk SSD (see kernels/ssd_chunk.py).  Accepts the
    mamba2 layout [B, nc, Q, H, ...] and flattens to the kernel grid."""
    from repro.kernels.ssd_chunk import ssd_intra_chunk
    return ssd_intra_chunk(c, b, x, dt, dac, interpret=interpret)
