"""Pallas TPU kernel for the Mamba-2 SSD intra-chunk term.

The SSD ("state-space duality") chunked scan splits into (a) an
attention-like intra-chunk quadratic term and (b) a cheap inter-chunk
recurrence.  (a) is the compute hot-spot (O(S*Q) per head) and maps onto
the MXU as two chunk-local GEMMs with a fused decay mask:

  att[i, j] = (C_i . B_j) * exp(dAc_i - dAc_j) * dt_j   for j <= i
  y         = att @ x                                    [Q, P]

One grid step processes one (batch, chunk, head) block; Q (chunk length),
N (state) and P (head dim) tiles live entirely in VMEM (Q=64, N=128,
P=64 -> ~100 KB working set).  Validated in interpret mode against
ref.ssd_intra_ref; runs compiled on real TPUs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(c_ref, b_ref, x_ref, dt_ref, dac_ref, o_ref):
    c = c_ref[0]                      # [Q, N]
    b = b_ref[0]                      # [Q, N]
    x = x_ref[0]                      # [Q, P]
    dt = dt_ref[0]                    # [Q]
    dac = dac_ref[0]                  # [Q]
    q = c.shape[0]
    s = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [Q, Q]
    seg = dac[:, None] - dac[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    att = jnp.where(tri, s * jnp.exp(seg), 0.0) * dt[None, :]
    y = jnp.dot(att.astype(x.dtype), x,
                preferred_element_type=jnp.float32)              # [Q, P]
    o_ref[0] = y.astype(o_ref.dtype)


def ssd_intra_chunk(c: jax.Array, b: jax.Array, x: jax.Array,
                    dt: jax.Array, dac: jax.Array,
                    interpret: bool = None) -> jax.Array:
    """Batched intra-chunk SSD.

    c, b: [G, Q, N]; x: [G, Q, P]; dt, dac: [G, Q] (dt post-softplus,
    dac = within-chunk cumsum of dt*A).  Returns y: [G, Q, P].
    G flattens (batch x chunks x heads) -- the grid dimension.
    """
    g, q, n = c.shape
    p = x.shape[-1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return pl.pallas_call(
        _kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, q, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, p), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q), lambda i: (i, 0)),
            pl.BlockSpec((1, q), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, p), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, q, p), x.dtype),
        interpret=interpret,
    )(c, b, x, dt, dac)
