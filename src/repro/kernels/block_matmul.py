"""Pallas TPU blocked matmul with fused bias + GELU epilogue.

This is the compute hot-spot of WeatherMixer: the paper reduces the whole
model to dense matmuls (its Table 1 workloads are pure GEMM chains), so
the kernel-level contribution here is an MXU-shaped GEMM:

  y = epilogue(x @ w.T + b)      x: [M, K], w: [N, K], y: [M, N]

TPU adaptation (DESIGN.md): tiles are MXU-aligned (multiples of 128 on
the matmul dims), the K-loop accumulates into a float32 VMEM scratch
(HBM -> VMEM -> MXU), and the epilogue (bias add + GELU of the mixer MLP's
first linear) is fused into the final K-step so the activation never
round-trips to HBM.  Grid order (M, N, K) keeps the x-tile resident while
sweeping N.

Validated in interpret mode on CPU against ref.py (the pure-jnp oracle);
on real TPU hardware the same pallas_call runs compiled.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def sublane(dtype) -> int:
    """Minimum second-to-last tile dim for ``dtype`` on the TPU (f32 8,
    bf16 16, int8/fp8 32) -- the single source of truth for both the
    block shrink in ops.block_dims and the legality assert below."""
    return {4: 8, 2: 16, 1: 32}.get(jnp.dtype(dtype).itemsize, 8)


def _kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, n_k: int,
            epilogue: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        out = acc_ref[...]
        if b_ref is not None:
            out = out + b_ref[...].astype(jnp.float32)[None, :]
        if epilogue == "gelu":
            out = jax.nn.gelu(out)
        elif epilogue == "silu":
            out = jax.nn.silu(out)
        o_ref[...] = out.astype(o_ref.dtype)


def block_matmul(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
                 *, block_m: int = 256, block_n: int = 256,
                 block_k: int = 512, epilogue: str = "none",
                 interpret: bool = None) -> jax.Array:
    """y = epilogue(x @ w.T + b).  x: [M, K]; w: [N, K]; b: [N] or None.

    M, N, K must be multiples of the block sizes (ops.py pads).
    Block sizes default to MXU-aligned (multiples of 128) tiles whose
    working set (bm*bk + bn*bk + bm*bn*4) fits comfortably in ~16 MB VMEM.
    """
    m, k = x.shape
    n, k2 = w.shape
    assert k == k2, (x.shape, w.shape)
    assert x.dtype == w.dtype, (
        f"block_matmul needs one operand dtype (got {x.dtype} vs "
        f"{w.dtype}); cast at the linear-apply boundary (ops.py does)")
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        f"shape ({m},{n},{k}) not divisible by blocks "
        f"({block_m},{block_n},{block_k})")
    # bf16 tiles need a 16-row sublane (f32: 8); ops.block_dims floors the
    # block sizes accordingly, so by here block_m is already legal
    sl = sublane(x.dtype)
    assert block_m % sl == 0 or block_m == m, (
        f"block_m={block_m} below the {jnp.dtype(x.dtype).name} sublane "
        f"floor {sl}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n_k = k // block_k
    grid = (m // block_m, n // block_n, n_k)

    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((block_n, block_k), lambda i, j, kk: (j, kk)),
    ]
    args = [x, w]
    if b is not None:
        in_specs.append(pl.BlockSpec((block_n,), lambda i, j, kk: (j,)))
        args.append(b)
        kernel = functools.partial(_kernel, n_k=n_k, epilogue=epilogue)
    else:
        kernel = functools.partial(
            lambda x_ref, w_ref, o_ref, acc_ref, **kw:
            _kernel(x_ref, w_ref, None, o_ref, acc_ref, **kw),
            n_k=n_k, epilogue=epilogue)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(*args)
