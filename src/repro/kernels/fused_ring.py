"""One-kernel ring: the paper's §4 schedule as a single ``pallas_call``.

``impl="ring_chunked"`` (core/jigsaw.py) interleaves per-chunk GEMMs with
``ppermute`` hops, but GEMMs and collectives remain *separate HLOs* -- the
overlap is whatever XLA's scheduler decides.  This module closes that gap:
the whole p-step schedule -- chunk GEMM, hop add, remote send -- runs inside
ONE ``pallas_call`` per ring, so hop *h*'s DMA is guaranteed in flight while
chunk *h+1*'s MXU GEMM executes (DESIGN.md §11).

Layout (inside the 1-D Jigsaw shard_map; see ``jigsaw_matmul_1d``):
  x: [..., d/p] local activation block     w: [m, d/p] local weight block
  out: [..., m/p] -- rank r's chunk of ``X @ W.T`` (reduce-scattered).

Schedule (grid step ``s`` on rank ``my``, p = ring size):
  * compute chunk ``j_s = (my - 1 - s) % p``'s GEMM; the w-chunk BlockSpec
    index_map walks that order, so the grid pipeline's double-buffered
    operand fetch IS the paper's chunk prefetch,
  * add the partial sum that arrived on hop ``s-1`` (``accum_dtype``),
  * cast down to the wire dtype (``x.dtype``) and start hop ``s``'s
    ``make_async_remote_copy`` to the ring successor -- the DMA flies
    while step ``s+1``'s GEMM runs.
The cast points (wire = x.dtype, hop adds in accum_dtype) are exactly
``ring_reduce_scatter``'s, so ``ring_fused == ring`` stays bit-identical
under every precision policy.

Deterministic fallback (CPU / interpret mode / VMEM-guard trips): the same
schedule lowered to chunk-granular GEMMs (honouring ``kernel=``, i.e. the
MXU-tiled ops.matmul in interpret mode) interleaved with ``ppermute`` --
semantically ``ring_matmul_chunked``, bit-identical to ``ring``, so parity
tests run everywhere.  What the fallback does NOT prove: the RDMA slot
discipline and in-kernel overlap of the TPU path (hardware-only).

Backward = the transposed schedule: the cotangent of a reduce-scattered
output is its ring ALLGATHER (rank-ordered); the fallback then runs the
monolithic local backward GEMMs via ``jax.vjp``, which reproduces
AD-of-``ring`` bit-for-bit (every wire cast round-trips losslessly and the
chunk scatter is disjoint).  On TPU the same fused kernel runs with the
transposed schedule: dy chunks ride the ring, each hop's arrival feeds a
dw-chunk GEMM while dx accumulates in f32 (reduction order over the m dim
differs from XLA AD there -- TPU-only, documented in DESIGN.md §11).

Also here: the Pallas transposed-Cannon step kernel (``cannon_t_step``)
used by ``jigsaw_matmul_2d_t`` under ``kernel="pallas"`` -- fused
``acc + w @ x`` multiply-accumulate with f32 VMEM accumulation and a
custom VJP whose backward GEMMs run the same machinery -- plus the fused
q-hop TPU variant where the rotate steps are in-kernel remote copies.
"""
from __future__ import annotations

import functools
import math
import warnings
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # pltpu imports cleanly on CPU builds of jax; guard anyway.
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover - exotic builds only
    pltpu = None

from repro.kernels import ops
from repro.kernels.block_matmul import sublane

# Per-core VMEM we allow the fused kernel to pin (16 MB on v4/v5 cores,
# minus headroom for the pipeline's own double buffers).
VMEM_BUDGET_BYTES = 12 * 1024 * 1024

_WARNED: set = set()


def _warn_once(key, msg: str) -> None:
    if key not in _WARNED:
        _WARNED.add(key)
        warnings.warn(msg, stacklevel=3)


# --------------------------------------------------------------------------
# VMEM-budget guard + path selection
# --------------------------------------------------------------------------

def ring_footprint_bytes(rows: int, d_local: int, m: int, p: int,
                         x_dtype, accum_dtype) -> int:
    """VMEM bytes the fused forward kernel pins for one ring.

    x block + double-buffered w chunk (the grid pipeline keeps two) +
    send/recv ring buffers (2 slots each, wire dtype) + the in-flight hop
    accumulator + the output chunk.
    """
    wire = jnp.dtype(x_dtype).itemsize
    acc = jnp.dtype(accum_dtype).itemsize if accum_dtype else wire
    mc = max(m // max(p, 1), 1)
    return int(rows * d_local * wire            # x block (resident)
               + 2 * mc * d_local * wire        # w chunk, double-buffered
               + 4 * rows * mc * wire           # send/recv bufs, 2 slots each
               + rows * mc * max(acc, 4)        # hop accumulator
               + rows * mc * wire)              # output chunk


def fits_vmem(rows: int, d_local: int, m: int, p: int, x_dtype,
              accum_dtype, budget: Optional[int] = None) -> bool:
    budget = VMEM_BUDGET_BYTES if budget is None else budget
    return ring_footprint_bytes(rows, d_local, m, p, x_dtype,
                                accum_dtype) <= budget


def _select_path(rows: int, d_local: int, m: int, p: int, x_dtype,
                 accum_dtype, mesh_axes: Optional[Sequence[str]],
                 axis_name: str, backend: Optional[str] = None,
                 budget: Optional[int] = None) -> str:
    """Choose ``"tpu"`` (single fused pallas_call) or ``"fallback"``
    (chunk-granular schedule).  Parameterized on ``backend``/``budget`` so
    the guard logic itself is testable on CPU."""
    backend = backend or jax.default_backend()
    if backend != "tpu" or pltpu is None:
        return "fallback"
    if mesh_axes is None or axis_name not in mesh_axes:
        # Neighbour addressing needs every mesh axis's coordinate; a
        # partially-manual mesh (or a caller that didn't thread the axis
        # names) cannot build them.
        _warn_once(("axes", axis_name,
                    None if mesh_axes is None else tuple(mesh_axes)),
                   "fused_ring: cannot address ring neighbours (mesh axes "
                   f"unavailable for ring {axis_name!r}); falling back to "
                   "the chunk-granular ring_chunked schedule")
        return "fallback"
    if not fits_vmem(rows, d_local, m, p, x_dtype, accum_dtype,
                     budget=budget):
        fp = ring_footprint_bytes(rows, d_local, m, p, x_dtype, accum_dtype)
        _warn_once(("vmem", rows, d_local, m, p),
                   f"fused_ring: chunk tiles need ~{fp / 2**20:.1f} MiB "
                   "VMEM > budget; falling back to the chunk-granular "
                   "ring_chunked schedule")
        return "fallback"
    return "tpu"


# --------------------------------------------------------------------------
# Shared helpers (kernels-local so core -> kernels stays one-way)
# --------------------------------------------------------------------------

def _local_mm(x: jax.Array, w: jax.Array, accum_dtype, kernel: str
              ) -> jax.Array:
    """x [..., k] x w [m, k] -> [..., m]; mirrors jigsaw._local_matmul so
    the fallback honours the ``kernel=`` knob with identical numerics."""
    if kernel == "pallas":
        return ops.matmul_nd(x, w, None, epilogue="none")
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=accum_dtype or x.dtype)


def _rank_order_all_gather(x: jax.Array, axis_name: str, p: int
                           ) -> jax.Array:
    """The backward ring: ring allgather of the output cotangent, reordered
    into rank order -- the transpose of the forward reduce-scatter.  Every
    hop ships dy.dtype bytes (same wire format as forward)."""
    if p == 1:
        return x
    idx = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % p) for i in range(p)]
    pieces = [x]
    cur = x
    for _ in range(p - 1):
        cur = jax.lax.ppermute(cur, axis_name, perm)
        pieces.append(cur)
    # piece t originated at rank (idx - t) % p; reorder to rank order.
    stacked = jnp.stack(pieces, axis=0)
    order = (idx - jnp.arange(p, dtype=jnp.int32)) % p
    inv = jnp.zeros((p,), jnp.int32).at[order].set(
        jnp.arange(p, dtype=jnp.int32))
    stacked = jnp.take(stacked, inv, axis=0)
    return jnp.concatenate([stacked[j] for j in range(p)], axis=-1)


def _ring_neighbors(axis_name: str, p: int,
                    mesh_axes: Optional[Sequence[str]]):
    """(succ_id, pred_id, device_id_type) for the ring RDMA.

    With a single-axis mesh the ring position IS the logical device id.
    With a multi-axis mesh we build full MESH coordinates from the manual
    axis indices (``mesh_axes`` = mesh.axis_names threaded down from
    jigsaw_linear), replacing the ring axis's coordinate.
    """
    my = jax.lax.axis_index(axis_name)
    if mesh_axes is None or tuple(mesh_axes) == (axis_name,):
        return ((my + 1) % p,), ((my - 1) % p,), pltpu.DeviceIdType.LOGICAL
    coords = [jax.lax.axis_index(a) for a in mesh_axes]
    k = list(mesh_axes).index(axis_name)
    succ = list(coords)
    pred = list(coords)
    succ[k] = (my + 1) % p
    pred[k] = (my - 1) % p
    return tuple(succ), tuple(pred), pltpu.DeviceIdType.MESH


# --------------------------------------------------------------------------
# TPU forward kernel: the fused multi-hop ring
# --------------------------------------------------------------------------
#
# RDMA slot discipline (hop h, double-buffered):
#   src = send_buf[h % 2] (mine) -> dst = recv_buf[h % 2] (successor's).
# Safety of reusing slots every other hop:
#   * my send_buf[h % 2] is rewritten at step h; its previous use was hop
#     h-2's send, whose completion was waited at step h-1 (hop(h-1).wait()
#     covers my send sem);
#   * my hop-h payload lands in the successor's recv_buf[h % 2], whose
#     previous content (hop h-2) they consumed at their step h-1 BEFORE
#     starting their hop h-1 send; my hop-h start happens-after I received
#     their hop h-1, hence after that consumption.  No credits needed.

def _ring_fwd_kernel(idx_ref, x_ref, w_ref, o_ref,
                     send_buf, recv_buf, send_sem, recv_sem, *,
                     p: int, acc_dtype, mesh_axes, axis_name):
    s = pl.program_id(0)
    wire = o_ref.dtype
    # Chunk GEMM for this grid step.  w_ref is already chunk
    # (my - 1 - s) % p: the BlockSpec index_map walks the ring order, so
    # Pallas' pipelined operand fetch double-buffers the chunk loads.
    # The MXU accumulates in f32 natively; the wire round-trip below puts
    # the cast points exactly where ring_reduce_scatter has them.
    y = jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    y = y.astype(wire).astype(acc_dtype)

    if p == 1:
        o_ref[...] = y.astype(wire)
        return

    succ, pred, id_type = _ring_neighbors(axis_name, p, mesh_axes)

    def hop(h):
        return pltpu.make_async_remote_copy(
            src_ref=send_buf.at[h % 2], dst_ref=recv_buf.at[h % 2],
            send_sem=send_sem.at[h % 2], recv_sem=recv_sem.at[h % 2],
            device_id=succ, device_id_type=id_type)

    @pl.when(s == 0)
    def _first():
        # Neighbour barrier: no RDMA until both neighbours entered the
        # kernel (their buffers exist); required before the first remote
        # DMA of a collective kernel.
        barrier = pltpu.get_barrier_semaphore()
        pltpu.semaphore_signal(barrier, 1, device_id=succ,
                               device_id_type=id_type)
        pltpu.semaphore_signal(barrier, 1, device_id=pred,
                               device_id_type=id_type)
        pltpu.semaphore_wait(barrier, 2)
        send_buf[0] = y.astype(wire)
        hop(0).start()

    @pl.when(jnp.logical_and(s > 0, s < p - 1))
    def _mid():
        # hop(s-1).wait(): my hop s-1 send drained AND the predecessor's
        # hop s-1 payload arrived -- then fuse add + cast + next send,
        # all while step s+1's w chunk is already being fetched.
        hop(s - 1).wait()
        tot = recv_buf[(s - 1) % 2].astype(acc_dtype) + y
        send_buf[s % 2] = tot.astype(wire)
        hop(s).start()

    @pl.when(s == p - 1)
    def _last():
        hop(s - 1).wait()
        tot = recv_buf[(s - 1) % 2].astype(acc_dtype) + y
        o_ref[...] = tot.astype(wire)


def _ring_fwd_tpu(x: jax.Array, w: jax.Array, axis_name: str, p: int,
                  acc_dt, mesh_axes) -> jax.Array:
    lead = x.shape[:-1]
    rows = math.prod(lead) if lead else 1
    d_local = x.shape[-1]
    mc = w.shape[0] // p
    x2 = x.reshape(rows, d_local)
    my = jax.lax.axis_index(axis_name).astype(jnp.int32).reshape(1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(p,),
        in_specs=[
            pl.BlockSpec((rows, d_local), lambda s, idx: (0, 0)),
            # chunk (my - 1 - s) % p: the ring walk order.
            pl.BlockSpec((mc, d_local),
                         lambda s, idx: ((idx[0] - 1 - s) % p, 0)),
        ],
        out_specs=pl.BlockSpec((rows, mc), lambda s, idx: (0, 0)),
        scratch_shapes=[
            pltpu.VMEM((2, rows, mc), x.dtype),   # send_buf
            pltpu.VMEM((2, rows, mc), x.dtype),   # recv_buf
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_ring_fwd_kernel, p=p,
                          acc_dtype=jnp.dtype(acc_dt),
                          mesh_axes=mesh_axes, axis_name=axis_name),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows, mc), x.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",), collective_id=0),
    )(my, x2, w)
    return out.reshape(lead + (mc,))


# --------------------------------------------------------------------------
# TPU backward kernel: the same ring, transposed schedule
# --------------------------------------------------------------------------

def _ring_bwd_kernel(idx_ref, x_ref, w_ref, dy_ref, dx_ref, dw_ref,
                     dx_acc, send_buf, recv_buf, send_sem, recv_sem, *,
                     p: int, mesh_axes, axis_name):
    """Transposed schedule: dy chunks ride the SAME ring (allgather
    direction); hop s's arrival is rank (my - s) % p's dy chunk, which
    feeds that chunk's dw GEMM (pipelined out BlockSpec) while dx
    accumulates across all p chunks in f32.  Same slot discipline as
    forward."""
    s = pl.program_id(0)

    if p == 1:
        cur = dy_ref[...]
    else:
        succ, pred, id_type = _ring_neighbors(axis_name, p, mesh_axes)

        def hop(h):
            return pltpu.make_async_remote_copy(
                src_ref=send_buf.at[h % 2], dst_ref=recv_buf.at[h % 2],
                send_sem=send_sem.at[h % 2], recv_sem=recv_sem.at[h % 2],
                device_id=succ, device_id_type=id_type)

        @pl.when(s == 0)
        def _first():
            barrier = pltpu.get_barrier_semaphore()
            pltpu.semaphore_signal(barrier, 1, device_id=succ,
                                   device_id_type=id_type)
            pltpu.semaphore_signal(barrier, 1, device_id=pred,
                                   device_id_type=id_type)
            pltpu.semaphore_wait(barrier, 2)
            send_buf[0] = dy_ref[...]
            hop(0).start()

        @pl.when(jnp.logical_and(s > 0, s < p - 1))
        def _mid():
            hop(s - 1).wait()
            send_buf[s % 2] = recv_buf[(s - 1) % 2]
            hop(s).start()

        @pl.when(s == p - 1)
        def _lastwait():
            hop(s - 1).wait()

        cur = jnp.where(s == 0, dy_ref[...], recv_buf[(s - 1) % 2])

    # dw chunk for rank (my - s) % p's rows (out BlockSpec walks them):
    # dw_j = dy_j^T @ x.
    dw_ref[...] = jax.lax.dot_general(
        cur, x_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(dw_ref.dtype)
    # dx accumulates every chunk's contribution in f32 (reduction order
    # over m differs from XLA AD's monolithic dot -- TPU-only divergence,
    # DESIGN.md §11).
    @pl.when(s == 0)
    def _zero():
        dx_acc[...] = jnp.zeros_like(dx_acc)
    dx_acc[...] += jax.lax.dot_general(
        cur, w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    @pl.when(s == p - 1)
    def _emit():
        dx_ref[...] = dx_acc[...].astype(dx_ref.dtype)


def _ring_bwd_tpu(x: jax.Array, w: jax.Array, dy: jax.Array,
                  axis_name: str, p: int, mesh_axes
                  ) -> Tuple[jax.Array, jax.Array]:
    lead = x.shape[:-1]
    rows = math.prod(lead) if lead else 1
    d_local = x.shape[-1]
    mc = w.shape[0] // p
    x2 = x.reshape(rows, d_local)
    dy2 = dy.reshape(rows, mc)
    my = jax.lax.axis_index(axis_name).astype(jnp.int32).reshape(1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(p,),
        in_specs=[
            pl.BlockSpec((rows, d_local), lambda s, idx: (0, 0)),
            # w chunk for the dy chunk arriving at step s: (my - s) % p.
            pl.BlockSpec((mc, d_local),
                         lambda s, idx: ((idx[0] - s) % p, 0)),
            pl.BlockSpec((rows, mc), lambda s, idx: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((rows, d_local), lambda s, idx: (0, 0)),
            pl.BlockSpec((mc, d_local),
                         lambda s, idx: ((idx[0] - s) % p, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((rows, d_local), jnp.float32),   # dx accumulator
            pltpu.VMEM((2, rows, mc), dy.dtype),        # send_buf
            pltpu.VMEM((2, rows, mc), dy.dtype),        # recv_buf
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    dx, dw = pl.pallas_call(
        functools.partial(_ring_bwd_kernel, p=p, mesh_axes=mesh_axes,
                          axis_name=axis_name),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((rows, d_local), x.dtype),
                   jax.ShapeDtypeStruct(w.shape, w.dtype)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",), collective_id=1),
    )(my, x2, w, dy2)
    return dx.reshape(x.shape), dw


# --------------------------------------------------------------------------
# The fused ring op (custom VJP; called inside the Jigsaw shard_map)
# --------------------------------------------------------------------------

def _chunk_walk(x, w, axis_name, p, acc_dt, kernel):
    """Chunk-granular fallback schedule: GEMM chunk j right before hop j's
    ppermute -- ring_matmul_chunked's walk with identical cast points, so
    the fallback stays bit-identical to ``ring`` everywhere."""
    m = w.shape[0]
    if m % p != 0:
        raise ValueError(f"fused_ring: out dim {m} not divisible by {p}")
    chunk = m // p
    idx = jax.lax.axis_index(axis_name)

    def chunk_mm(j):
        wj = jax.lax.dynamic_slice_in_dim(w, j * chunk, chunk, axis=0)
        y = _local_mm(x, wj, acc_dt, kernel).astype(x.dtype)
        return y.astype(acc_dt)

    perm = [(i, (i + 1) % p) for i in range(p)]
    acc = chunk_mm((idx + p - 1) % p)
    for s in range(p - 1):
        acc = jax.lax.ppermute(acc.astype(x.dtype), axis_name, perm)
        acc = acc.astype(acc_dt) + chunk_mm((idx - 2 - s) % p)
    return acc.astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6))
def _fused(x, w, axis_name, p, acc_name, kernel, mesh_axes):
    acc_dt = jnp.dtype(acc_name)
    if p == 1:
        return _local_mm(x, w, acc_dt, kernel).astype(x.dtype)
    lead = x.shape[:-1]
    rows = math.prod(lead) if lead else 1
    path = _select_path(rows, x.shape[-1], w.shape[0], p, x.dtype, acc_dt,
                        mesh_axes, axis_name)
    if path == "tpu":
        return _ring_fwd_tpu(x, w, axis_name, p, acc_dt, mesh_axes)
    return _chunk_walk(x, w, axis_name, p, acc_dt, kernel)


def _fused_fwd(x, w, axis_name, p, acc_name, kernel, mesh_axes):
    return _fused(x, w, axis_name, p, acc_name, kernel, mesh_axes), (x, w)


def _fused_bwd(axis_name, p, acc_name, kernel, mesh_axes, res, dy):
    x, w = res
    acc_dt = jnp.dtype(acc_name)
    lead = x.shape[:-1]
    rows = math.prod(lead) if lead else 1
    if p > 1 and _select_path(rows, x.shape[-1], w.shape[0], p, x.dtype,
                              acc_dt, mesh_axes, axis_name) == "tpu":
        return _ring_bwd_tpu(x, w, dy, axis_name, p, mesh_axes)
    # Transposed schedule, fallback form: gather the full cotangent (the
    # backward ring), then the monolithic local backward GEMMs.  This is
    # the exact program jax.grad builds for impl="ring" -- the allgather is
    # value-exact (disjoint chunks, lossless wire round-trips), so grads
    # are bit-identical to ``ring``'s.
    cot = _rank_order_all_gather(dy, axis_name, p)

    def primal(xx, ww):
        return _local_mm(xx, ww, acc_dt, kernel).astype(x.dtype)

    _, vjp = jax.vjp(primal, x, w)
    return vjp(cot)


_fused.defvjp(_fused_fwd, _fused_bwd)


def fused_ring_matmul(x: jax.Array, w: jax.Array, *, axis_name: str,
                      axis_size: int,
                      accum_dtype=jnp.float32, kernel: str = "xla",
                      mesh_axes: Optional[Sequence[str]] = None
                      ) -> jax.Array:
    """The one-kernel ring matmul (``impl="ring_fused"``).

    x: local [..., d/p]; w: local [m, d/p] -> local [..., m/p] chunk of
    ``X @ W.T``.  Must be called inside shard_map with ``axis_name``
    manual.  On TPU (and within the VMEM budget) the whole p-step
    schedule is one ``pallas_call``; elsewhere a deterministic
    chunk-granular fallback runs.  Both are bit-identical to ``ring``
    (forward AND grads) under fp32 and bf16 policies.

    ``mesh_axes``: the mesh's manual axis names in mesh order -- required
    by the TPU path to address ring neighbours on a multi-axis mesh
    (ignored by the fallback).
    """
    acc_name = jnp.dtype(accum_dtype).name if accum_dtype is not None \
        else jnp.dtype(x.dtype).name
    return _fused(x, w, axis_name, int(axis_size), acc_name, kernel,
                  None if mesh_axes is None else tuple(mesh_axes))


# --------------------------------------------------------------------------
# Pallas transposed-Cannon (the 2-D token-mix promotion)
# --------------------------------------------------------------------------

def _wx_kernel(w_ref, x_ref, a_ref, o_ref, acc_ref, *, n_k: int):
    """One (L, m, c) output block of ``out = a + w @ x``: K-blocked MXU
    GEMM with f32 VMEM accumulation, cross-step accumulator add fused into
    the epilogue (the Cannon multiply-accumulate in one kernel)."""
    kk = pl.program_id(3)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        w_ref[...], x_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(kk == n_k - 1)
    def _epilogue():
        o_ref[0] = (a_ref[0].astype(jnp.float32)
                    + acc_ref[...]).astype(o_ref.dtype)


def _wx_raw(w: jax.Array, x: jax.Array, a: jax.Array, out_dtype,
            block_m: int = 256, block_c: int = 256, block_k: int = 512,
            interpret: Optional[bool] = None) -> jax.Array:
    """w [m, t] @ x [L, t, c] + a [L, m, c] -> [L, m, c] (out_dtype)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    ll, t, c = x.shape
    m = w.shape[0]
    # m: sublane of w/out; t: lane of w AND sublane of x (128 covers both);
    # c: lane of x/out.
    bm = min(block_m, _ru(m, sublane(w.dtype)))
    bk = min(block_k, _ru(t, 128))
    bc = min(block_c, _ru(c, 128))
    wp = ops._pad_to(ops._pad_to(w, 0, bm), 1, bk)
    xp = ops._pad_to(ops._pad_to(x, 1, bk), 2, bc)
    ap = ops._pad_to(ops._pad_to(a, 1, bm), 2, bc)
    mp, tp_, cp = wp.shape[0], wp.shape[1], xp.shape[2]
    n_k = tp_ // bk
    grid = (ll, mp // bm, cp // bc, n_k)
    out = pl.pallas_call(
        functools.partial(_wx_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda b, i, j, kk: (i, kk)),
            pl.BlockSpec((1, bk, bc), lambda b, i, j, kk: (b, kk, j)),
            pl.BlockSpec((1, bm, bc), lambda b, i, j, kk: (b, i, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bc), lambda b, i, j, kk: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((ll, mp, cp), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bc), jnp.float32)],
        interpret=interpret,
    )(wp, xp, ap)
    return out[:, :m, :c]


def _ru(n: int, mult: int) -> int:
    return -(-n // mult) * mult


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _wx_acc(w, x, a, out_name):
    return _wx_raw(w, x, a, jnp.dtype(out_name))


def _wx_acc_fwd(w, x, a, out_name):
    return _wx_acc(w, x, a, out_name), (w, x)


def _wx_acc_bwd(out_name, res, dy):
    w, x = res
    # d(a + w @ x): da = dy (identity in the accum dtype); the two GEMMs
    # run the same blocked Pallas machinery (ops-style transposed args).
    da = dy
    ll, t, c = x.shape
    m = w.shape[0]
    # dw[m, t] = sum_l dy_l @ x_l^T: flatten (L, c) into one contraction.
    dyt = jnp.moveaxis(dy, 1, 0).reshape(m, ll * c)
    xt = jnp.moveaxis(x, 1, 0).reshape(t, ll * c)
    dw = ops.matmul(dyt.astype(x.dtype), xt, None,
                    epilogue="none").astype(w.dtype)
    # dx[l, t, c] = w^T @ dy_l: the same wx kernel with w transposed
    # (transpose-in-backward, as in ops._matmul_bwd).
    zeros = jnp.zeros((ll, t, c), dy.dtype)
    dx = _wx_raw(w.T.astype(dy.dtype), dy, zeros,
                 jnp.dtype(out_name)).astype(x.dtype)
    return dw, dx, da


_wx_acc.defvjp(_wx_acc_fwd, _wx_acc_bwd)


def cannon_t_step(w: jax.Array, x: jax.Array, acc: Optional[jax.Array],
                  *, accum_dtype=jnp.float32) -> jax.Array:
    """One transposed-Cannon multiply-accumulate step on the MXU:
    ``acc + w @ x`` contracting x's second-to-last dim.

    w: [m_l, t_l]; x: [..., t_l, c_l]; acc: [..., m_l, c_l] in
    ``accum_dtype`` (None starts a fresh accumulator).  The cross-step add
    is fused into the GEMM epilogue so each Cannon step is ONE pallas_call;
    differentiable via a custom VJP whose backward GEMMs run the same
    blocked kernel.
    """
    out_dt = jnp.dtype(accum_dtype or x.dtype)
    lead = x.shape[:-2]
    ll = math.prod(lead) if lead else 1
    t, c = x.shape[-2], x.shape[-1]
    m = w.shape[0]
    x3 = x.reshape(ll, t, c)
    if acc is None:
        a3 = jnp.zeros((ll, m, c), out_dt)
    else:
        a3 = acc.reshape(ll, m, c).astype(out_dt)
    y = _wx_acc(w, x3, a3, out_dt.name)
    return y.reshape(lead + (m, c))


# --------------------------------------------------------------------------
# TPU fused transposed-Cannon: q rotate hops as in-kernel remote copies
# --------------------------------------------------------------------------

def cannon_footprint_bytes(ll: int, m_l: int, t_l: int, c_l: int,
                           x_dtype) -> int:
    """VMEM for the fused Cannon: both operands double-buffered (send +
    recv each) + the f32 block accumulator."""
    e = jnp.dtype(x_dtype).itemsize
    return int(4 * m_l * t_l * e + 4 * ll * t_l * c_l * e
               + ll * m_l * c_l * 4 + ll * m_l * c_l * e)


def _cannon_kernel(ij_ref, w_ref, x_ref, o_ref,
                   w_send, w_recv, x_send, x_recv, acc,
                   wss, wrs, xss, xrs, *, q: int, mesh_axes,
                   dom_axis: str, tp_axis: str):
    """Fused transposed-Cannon: grid step s multiplies the current (w, x)
    blocks into the f32 accumulator while BOTH rotate hops (w along tp,
    x along dom; perm (t, (t-1) % q), i.e. send to predecessor) fly as
    remote copies -- the rotate steps are in-kernel.  Skew happens once
    outside (operand alignment, not the hot loop).  Slot discipline as in
    the 1-D ring."""
    s = pl.program_id(0)
    if q > 1:
        w_succ, w_pred, id_t = _ring_neighbors(tp_axis, q, mesh_axes)
        x_succ, x_pred, _ = _ring_neighbors(dom_axis, q, mesh_axes)

        def hop(h, src, dst, ssem, rsem, to, ty):
            return pltpu.make_async_remote_copy(
                src_ref=src.at[h % 2], dst_ref=dst.at[h % 2],
                send_sem=ssem.at[h % 2], recv_sem=rsem.at[h % 2],
                device_id=to, device_id_type=ty)

        @pl.when(s == 0)
        def _first():
            barrier = pltpu.get_barrier_semaphore()
            for dev in (w_succ, w_pred, x_succ, x_pred):
                pltpu.semaphore_signal(barrier, 1, device_id=dev,
                                       device_id_type=id_t)
            pltpu.semaphore_wait(barrier, 4)

        @pl.when(s > 0)
        def _wait():
            hop(s - 1, w_send, w_recv, wss, wrs, w_pred, id_t).wait()
            hop(s - 1, x_send, x_recv, xss, xrs, x_pred, id_t).wait()

        cur_w = jnp.where(s == 0, w_ref[...], w_recv[(s - 1) % 2])
        cur_x = jnp.where(s == 0, x_ref[...], x_recv[(s - 1) % 2])

        @pl.when(s < q - 1)
        def _send():
            w_send[s % 2] = cur_w
            x_send[s % 2] = cur_x
            hop(s, w_send, w_recv, wss, wrs, w_pred, id_t).start()
            hop(s, x_send, x_recv, xss, xrs, x_pred, id_t).start()
    else:
        cur_w = w_ref[...]
        cur_x = x_ref[...]

    @pl.when(s == 0)
    def _zero():
        acc[...] = jnp.zeros_like(acc)
    # [m_l, t_l] x [L, t_l, c_l] -> [m_l, L, c_l]
    acc[...] += jax.lax.dot_general(
        cur_w, cur_x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(s == q - 1)
    def _emit():
        o_ref[...] = acc[...].astype(o_ref.dtype)


def _cannon_fwd_tpu(w: jax.Array, x: jax.Array, *, dom_axis: str,
                    tp_axis: str, q: int, accum_dtype, mesh_axes
                    ) -> jax.Array:
    """q multiply-accumulate steps + 2(q-1) rotate hops in ONE pallas_call.
    Inputs are the already-skewed local blocks; returns [L, m_l, c_l]
    (moved from the kernel's [m_l, L, c_l] accumulator layout)."""
    ll, t_l, c_l = x.shape
    m_l = w.shape[0]
    out_dt = jnp.dtype(accum_dtype or x.dtype)
    ij = jnp.zeros((1,), jnp.int32)  # placeholder prefetch (ids via axes)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(q,),
        in_specs=[
            pl.BlockSpec((m_l, t_l), lambda s, ij: (0, 0)),
            pl.BlockSpec((ll, t_l, c_l), lambda s, ij: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((m_l, ll, c_l), lambda s, ij: (0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((2, m_l, t_l), w.dtype),
            pltpu.VMEM((2, m_l, t_l), w.dtype),
            pltpu.VMEM((2, ll, t_l, c_l), x.dtype),
            pltpu.VMEM((2, ll, t_l, c_l), x.dtype),
            pltpu.VMEM((m_l, ll, c_l), jnp.float32),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_cannon_kernel, q=q, mesh_axes=mesh_axes,
                          dom_axis=dom_axis, tp_axis=tp_axis),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m_l, ll, c_l), out_dt),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",), collective_id=2),
    )(ij, w, x)
    return jnp.moveaxis(out, 0, 1)


def cannon_t_loop(wl: jax.Array, xl: jax.Array, *, dom_axis: str,
                  tp_axis: str, q: int, accum_dtype) -> jax.Array:
    """The q-step transposed-Cannon loop on the step kernel: one fused
    multiply-accumulate pallas_call per step, rotate hops via ppermute.
    Operands must already be skewed.  Differentiable (cannon_t_step's
    custom VJP + ppermute's native transpose)."""
    acc = cannon_t_step(wl, xl, None, accum_dtype=accum_dtype)
    perm = [(t, (t - 1) % q) for t in range(q)]
    for _ in range(q - 1):
        wl = jax.lax.ppermute(wl, tp_axis, perm)
        xl = jax.lax.ppermute(xl, dom_axis, perm)
        acc = cannon_t_step(wl, xl, acc, accum_dtype=accum_dtype)
    return acc


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6))
def _fused_cannon(wl, xl, dom_axis, tp_axis, q, acc_name, mesh_axes):
    acc_dt = jnp.dtype(acc_name)
    lead = xl.shape[:-2]
    ll = math.prod(lead) if lead else 1
    if q > 1 and cannon_path(ll, wl.shape[0], wl.shape[1], xl.shape[-1],
                             xl.dtype, mesh_axes) == "tpu":
        y = _cannon_fwd_tpu(wl, xl.reshape((ll,) + xl.shape[-2:]),
                            dom_axis=dom_axis, tp_axis=tp_axis, q=q,
                            accum_dtype=acc_dt, mesh_axes=mesh_axes)
        return y.reshape(lead + y.shape[-2:])
    return cannon_t_loop(wl, xl, dom_axis=dom_axis, tp_axis=tp_axis,
                         q=q, accum_dtype=acc_dt)


def _fused_cannon_fwd(wl, xl, dom_axis, tp_axis, q, acc_name, mesh_axes):
    return (_fused_cannon(wl, xl, dom_axis, tp_axis, q, acc_name,
                          mesh_axes), (wl, xl))


def _fused_cannon_bwd(dom_axis, tp_axis, q, acc_name, mesh_axes, res, dy):
    # Backward of the fused q-hop kernel = backward of the step loop (same
    # math; the rotations transpose to reverse rotations via ppermute).
    wl, xl = res
    acc_dt = jnp.dtype(acc_name)
    _, vjp = jax.vjp(
        lambda w_, x_: cannon_t_loop(w_, x_, dom_axis=dom_axis,
                                     tp_axis=tp_axis, q=q,
                                     accum_dtype=acc_dt), wl, xl)
    return vjp(dy)


_fused_cannon.defvjp(_fused_cannon_fwd, _fused_cannon_bwd)


def fused_cannon_t(wl: jax.Array, xl: jax.Array, *, dom_axis: str,
                   tp_axis: str, q: int, accum_dtype=jnp.float32,
                   mesh_axes: Optional[Sequence[str]] = None) -> jax.Array:
    """Transposed-Cannon on the Pallas engine (already-skewed operands).

    On TPU within the VMEM budget the q multiply-accumulate steps AND the
    2(q-1) rotate hops run as ONE pallas_call (in-kernel remote copies);
    elsewhere one fused multiply-accumulate pallas_call per step with
    ppermute rotates.  Returns [..., m_l, c_l] in ``accum_dtype``.
    """
    acc_name = jnp.dtype(accum_dtype or xl.dtype).name
    return _fused_cannon(wl, xl, dom_axis, tp_axis, int(q), acc_name,
                         None if mesh_axes is None else tuple(mesh_axes))


def cannon_path(ll: int, m_l: int, t_l: int, c_l: int, x_dtype,
                mesh_axes: Optional[Sequence[str]],
                backend: Optional[str] = None,
                budget: Optional[int] = None) -> str:
    """``"tpu"`` when the fused q-hop Cannon kernel can run, else
    ``"step"`` (one pallas_call per Cannon step, rotates via ppermute)."""
    backend = backend or jax.default_backend()
    if backend != "tpu" or pltpu is None or mesh_axes is None:
        return "step"
    budget = VMEM_BUDGET_BYTES if budget is None else budget
    if cannon_footprint_bytes(ll, m_l, t_l, c_l, x_dtype) > budget:
        _warn_once(("cannon_vmem", ll, m_l, t_l, c_l),
                   "fused_ring: fused Cannon blocks exceed the VMEM "
                   "budget; using the per-step kernel with ppermute "
                   "rotates")
        return "step"
    return "tpu"
