"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def block_matmul_ref(x: jax.Array, w: jax.Array,
                     b: Optional[jax.Array] = None,
                     epilogue: str = "none") -> jax.Array:
    out = jax.lax.dot_general(x, w, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    if b is not None:
        out = out + b.astype(jnp.float32)[None, :]
    if epilogue == "gelu":
        out = jax.nn.gelu(out)
    elif epilogue == "silu":
        out = jax.nn.silu(out)
    return out.astype(x.dtype)


def mixer_mlp_ref(x: jax.Array, w1: jax.Array, b1: jax.Array,
                  w2: jax.Array, b2: jax.Array) -> jax.Array:
    """The WeatherMixer MLP: gelu(x @ w1.T + b1) @ w2.T + b2 over the last
    dim of a [..., rows, d_in] tensor."""
    h = block_matmul_ref(x.reshape(-1, x.shape[-1]), w1, b1, "gelu")
    y = block_matmul_ref(h, w2, b2, "none")
    return y.reshape(x.shape[:-1] + (w2.shape[0],))


def ssd_intra_ref(c, b, x, dt, dac):
    """Oracle for kernels/ssd_chunk.py: the intra-chunk SSD term.
    c, b: [G, Q, N]; x: [G, Q, P]; dt, dac: [G, Q]."""
    s = jnp.einsum("gin,gjn->gij", c.astype(jnp.float32),
                   b.astype(jnp.float32))
    seg = dac[:, :, None] - dac[:, None, :]
    q = c.shape[1]
    tri = jnp.tril(jnp.ones((q, q), bool))
    att = jnp.where(tri[None], s * jnp.exp(seg), 0.0) * dt[:, None, :]
    y = jnp.einsum("gij,gjp->gip", att, x.astype(jnp.float32))
    return y.astype(x.dtype)
