"""Forecast serving driver: a thin CLI over ``ForecastEngine``
(mirrors launch/train.py).

CPU-runnable (reduced configs, host-emulated data mesh) and
production-shaped from the same entry point:

  PYTHONPATH=src python -m repro.launch.serve --arch weathermixer-1b \
      [--ckpt out/ckpt-100] [--mesh-data 4] [--precision bf16] \
      [--requests 32] [--leads 1,2,4,8] [--mode continuous|drain] \
      [--buckets 1,2,4,8] [--coalesce-ms 0]

``--ckpt`` restores the params group of ANY training checkpoint
(whatever mesh it was saved on) onto the serving mesh
(checkpoint/serving.py); without it the engine serves fresh-initialized
weights, which is still useful for load testing.  Requests are
synthetic initial conditions from the weather dataset, submitted
up-front with leads cycling through ``--leads``; the engine coalesces,
batches continuously at rollout-step boundaries, and reports
requests/s + latency percentiles.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional, Sequence

from repro.configs.registry import ARCH_IDS
from repro.data.weather import WeatherDataConfig, WeatherDataset
from repro.serve.engine import ForecastEngine, ServeConfig


def serve(arch: str, *, ckpt: Optional[str] = None, requests: int = 32,
          leads: Sequence[int] = (1, 2, 4, 8), mesh_data: int = 1,
          precision: Optional[str] = None, mode: str = "continuous",
          buckets: Sequence[int] = (1, 2, 4, 8), coalesce_ms: float = 0.0,
          seed: int = 0, reduced: bool = True, warmup: bool = True,
          trace: Optional[str] = None,
          config_override=None, quiet: bool = False):
    """Build an engine, push ``requests`` synthetic forecasts through
    it, and return ``(results, engine, wall_seconds)``."""
    engine = ForecastEngine(
        arch, reduced=reduced, ckpt=ckpt, mesh_data=mesh_data,
        config_override=config_override,
        config=ServeConfig(buckets=tuple(buckets), mode=mode,
                           coalesce_s=coalesce_ms / 1e3,
                           precision=precision, seed=seed, trace=trace))
    cfg = engine.cfg
    ds = WeatherDataset(WeatherDataConfig(
        lat=cfg.wm_lat, lon=cfg.wm_lon, channels=cfg.wm_channels,
        seed=seed))
    fields = ds.sample_batch(0, requests)["fields"]
    if warmup:
        engine.warmup()
        if not quiet:
            print(f"[serve] warmup: {engine.stats['compiles']} compiles "
                  f"in {engine.stats['warmup_s']:.2f}s")
    t0 = time.perf_counter()
    results = [engine.submit(fields[i], leads[i % len(leads)])
               for i in range(requests)]
    engine.drain()
    wall = time.perf_counter() - t0
    if not quiet:
        s = engine.summary(results)
        src = (f"ckpt {ckpt} (step {engine.restored_step})" if ckpt
               else "fresh init")
        print(f"[serve] {arch} from {src} on mesh_data={mesh_data} "
              f"precision={engine.policy.name} mode={mode}")
        print(f"[serve] {requests} requests in {wall:.2f}s = "
              f"{requests / wall:.1f} req/s | p50 {s['p50_s'] * 1e3:.1f}ms "
              f"p95 {s['p95_s'] * 1e3:.1f}ms | {s['device_steps']} rollout "
              f"steps, {s['formed']} batch forms, {s['grown']} grows, "
              f"{s['compiles']} compiles (0 post-warmup = steady state)")
    out = engine.export_trace()
    if out and not quiet:
        print(f"[serve] trace -> {out}")
    return results, engine, wall


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="weathermixer-1b", choices=ARCH_IDS)
    ap.add_argument("--ckpt", default=None,
                    help="training checkpoint dir to serve (any saving "
                         "topology; params group only)")
    ap.add_argument("--full", action="store_true",
                    help="full (non-reduced) config -- needs real hardware")
    ap.add_argument("--mesh-data", type=int, default=1,
                    help="data-parallel serving mesh size (batch sharded, "
                         "params replicated)")
    ap.add_argument("--precision", default=None,
                    choices=["fp32", "bf16", "bf16_pure"],
                    help="serving precision policy (may differ from the "
                         "checkpoint's -- weights are cast on restore)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--leads", default="1,2,4,8",
                    help="comma-separated lead times (rollout steps), "
                         "assigned round-robin to requests")
    ap.add_argument("--mode", default="continuous",
                    choices=["continuous", "drain"],
                    help="continuous batching vs drain-and-refill baseline")
    ap.add_argument("--buckets", default="1,2,4,8",
                    help="padded batch buckets (one jit executable each)")
    ap.add_argument("--coalesce-ms", type=float, default=0.0,
                    help="idle burst-coalescing window")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None,
                    help="Chrome trace-event export path for the serving "
                         "spans + latency histograms")
    args = ap.parse_args()
    serve(args.arch, ckpt=args.ckpt, requests=args.requests,
          leads=[int(x) for x in args.leads.split(",")],
          mesh_data=args.mesh_data, precision=args.precision,
          mode=args.mode, buckets=[int(x) for x in args.buckets.split(",")],
          coalesce_ms=args.coalesce_ms, seed=args.seed,
          reduced=not args.full, trace=args.trace)


if __name__ == "__main__":
    main()
