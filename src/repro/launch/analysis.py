"""Compiled-artifact analysis: roofline terms from the dry-run.

Sources (CPU container, TPU v5e target -- no wall clock available):
  * ``compiled.cost_analysis()``  -> HLO FLOPs + bytes accessed (per-device
    program, post-SPMD-partitioning).
  * ``compiled.as_text()``        -> optimized HLO; we sum operand bytes of
    every all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute.  Collectives inside while-loop bodies (lax.scan
    over layers) are multiplied by the loop trip count, which we recover
    from the HLO constant the induction variable is compared against.

Roofline terms (seconds), per device:
  compute    = flops / PEAK_FLOPS
  memory     = bytes_accessed / HBM_BW
  collective = collective_bytes / ICI_BW
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

# TPU v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link (~usable per-chip here)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """bytes of an HLO type string like 'bf16[4,128]' or a tuple
    '(bf16[2], f32[3,3])'."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, float]

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """Split an HLO module's text into its computations.

    Handles both the post-optimization header form
    ``%name (params) -> type {`` and the pre-optimization short form
    ``name {`` (``compiler_ir(dialect='hlo')`` -- which the precision
    benchmarks parse, because backend legalization may rewrite
    collective dtypes: CPU widens bf16 collectives to f32)."""
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if (stripped.endswith("{") and not stripped.startswith("ROOT")
                and "=" not in stripped.split("(")[0]
                and not stripped.startswith("HloModule")):
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?"
                         r"\s*(?:->.*)?{$", stripped)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is not None:
            comps[cur].append(line)
        if line.startswith("}") or stripped == "}":
            cur = None
    return comps


def _find_trip_counts(hlo: str) -> Dict[str, int]:
    """Map while-body computation name -> trip count.

    XLA canonicalizes counted loops; we recover the count from the
    ``trip_count`` backend hint if present, else from the constant bound
    in the condition computation referenced by each while op.
    """
    trips: Dict[str, int] = {}
    # while ops: ... while(...), condition=%cond_name, body=%body_name
    for m in re.finditer(
            r"while\([^)]*\)[^\n]*condition=%?([\w\.\-]+)[^\n]*body=%?"
            r"([\w\.\-]+)", hlo):
        cond, body = m.groups()
        # find the condition computation and its comparison constant
        cm = re.search(
            re.escape(cond) + r"[^{]*{(.*?)\n}", hlo, re.S)
        count = None
        if cm:
            consts = re.findall(r"constant\((\d+)\)", cm.group(1))
            if consts:
                count = max(int(c) for c in consts)
        trips[body] = count if count else 1
    return trips


def collective_stats(hlo: str) -> CollectiveStats:
    comps = _split_computations(hlo)
    trips = _find_trip_counts(hlo)
    counts = {k: 0 for k in _COLLECTIVES}
    bts = {k: 0.0 for k in _COLLECTIVES}
    for comp_name, lines in comps.items():
        mult = 1
        # nested loops: multiply by every enclosing trip count whose body
        # matches; (single level is the common case for our scans)
        for body, t in trips.items():
            if comp_name == body or comp_name.startswith(body):
                mult = t
                break
        for line in lines:
            for kind in _COLLECTIVES:
                # match ' = TYPE kind(' and avoid -start/-done duplicates
                m = re.search(r"=\s+([^\s]+)\s+" + kind + r"(?:-start)?\(",
                              line)
                if m:
                    counts[kind] += mult
                    bts[kind] += mult * _shape_bytes(m.group(1))
                    break
    return CollectiveStats(counts, bts)


@dataclasses.dataclass
class Roofline:
    flops: float                  # per device
    bytes_accessed: float         # per device
    collective_bytes: float       # per device
    n_devices: int
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: Optional[float] = None
    useful_ratio: Optional[float] = None

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline_from(compiled, *, n_devices: int,
                  model_flops_total: Optional[float] = None,
                  peak=PEAK_FLOPS_BF16, hbm=HBM_BW, ici=ICI_BW) -> Roofline:
    ca = compiled.cost_analysis()
    flops = float(ca.get("flops", 0.0))
    bytes_acc = float(sum(v for k, v in ca.items()
                          if k.startswith("bytes accessed")
                          and "{" not in k.replace("{}", "")) or
                      ca.get("bytes accessed", 0.0))
    # 'bytes accessed' plain key is the total; operand-indexed keys are
    # the breakdown. Prefer the plain key when present.
    if "bytes accessed" in ca:
        bytes_acc = float(ca["bytes accessed"])
    stats = collective_stats(compiled.as_text())
    comp_s = flops / peak
    mem_s = bytes_acc / hbm
    coll_s = stats.total_bytes / ici
    terms = {"compute": comp_s, "memory": mem_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_total / n_devices if model_flops_total else None
    return Roofline(
        flops=flops, bytes_accessed=bytes_acc,
        collective_bytes=stats.total_bytes, n_devices=n_devices,
        compute_s=comp_s, memory_s=mem_s, collective_s=coll_s,
        bottleneck=bottleneck, model_flops=mf,
        useful_ratio=(mf / flops if (mf and flops) else None))


# ---------------------------------------------------------------------------
# Analytic FLOPs / HBM-bytes model
#
# XLA's cost_analysis() does NOT account for while-loop bodies (verified:
# flops are constant in n_layers under lax.scan), so the dry-run derives
# compute/memory roofline terms analytically from the exact matmul dims --
# we wrote the model code, so the dims are known precisely -- and uses the
# compiled HLO only for the collective schedule (trip counts recovered
# from the loop conditions) and the memory_analysis() fit proof.
# ---------------------------------------------------------------------------

def _dense_matmul_params(cfg) -> float:
    """Matmul-participating params per *layer stack* (excl. embeddings),
    counting each expert (for per-token math use active fraction)."""
    D = cfg.d_model
    hd = cfg.d_head
    attn = (D * cfg.n_heads * hd + 2 * D * cfg.n_kv_heads * hd
            + cfg.n_heads * hd * D) if cfg.n_heads else 0
    ffn = (3 if cfg.ffn_kind == "swiglu" else 2) * D * cfg.d_ff
    ssm = 0
    if cfg.ssm_heads:
        din = cfg.ssm_d_inner
        dinp = 2 * din + 2 * cfg.ssm_groups * cfg.ssm_state + cfg.ssm_heads
        ssm = D * dinp + din * D
    total = 0.0
    for i in range(cfg.n_layers):
        if cfg.family == "ssm" or not cfg.is_attn_layer(i):
            total += ssm
        else:
            total += attn
        if cfg.is_moe_layer(i):
            total += cfg.top_k * ffn     # active experts only
        elif cfg.d_ff:
            total += ffn
    return total


def flops_forward(cfg, batch: int, seq: int) -> Dict[str, float]:
    """Forward-pass FLOPs by component for one global batch."""
    D = cfg.d_model
    T = batch * seq
    out = {}
    out["matmul"] = 2.0 * _dense_matmul_params(cfg) * T
    # attention score/AV matmuls (causal not exploited, matching XLA)
    if cfg.n_heads:
        attn = 0.0
        for i in range(cfg.n_layers):
            if cfg.family == "ssm" or not cfg.is_attn_layer(i):
                continue
            w = cfg.layer_window(i)
            s_eff = min(seq, w) if w is not None else seq
            attn += 4.0 * batch * cfg.n_heads * cfg.d_head * seq * s_eff
        out["attention"] = attn
    # SSD chunked scan (intra-chunk quadratic + state einsums)
    if cfg.ssm_heads:
        Q = cfg.ssm_chunk
        H, Pd, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        n_ssm = sum(1 for i in range(cfg.n_layers)
                    if cfg.family == "ssm" or not cfg.is_attn_layer(i))
        per_tok = (2 * Q * H * N            # CB^T within chunk
                   + 2 * Q * H * Pd         # att @ x
                   + 6 * H * Pd * N)        # states + y_inter
        out["ssd_scan"] = n_ssm * T * per_tok
    # MoE dispatch/combine einsums
    if cfg.n_experts:
        n_moe = sum(1 for i in range(cfg.n_layers) if cfg.is_moe_layer(i))
        # dispatch [T,E,C]x[T,D] + combine: 2 einsums of 2*T*(k*cf)*D
        out["moe_dispatch"] = n_moe * 4.0 * T * cfg.top_k * cfg.capacity_factor * D
        out["router"] = n_moe * 2.0 * T * cfg.n_experts * D
    # LM head / embeddings
    if cfg.vocab_size:
        out["head"] = 2.0 * T * D * cfg.vocab_padded
    if cfg.family == "mixer":
        t_tok = (cfg.wm_lat // cfg.wm_patch) * (cfg.wm_lon // cfg.wm_patch)
        pin = cfg.wm_patch ** 2 * cfg.wm_channels
        B = batch
        out["matmul"] = 2.0 * B * (
            t_tok * pin * D * 2                                   # enc+dec
            + cfg.n_layers * (2 * t_tok * cfg.wm_d_tok * D        # token MLP
                              + 2 * t_tok * D * cfg.wm_d_ch))     # chan MLP
    return out


def flops_step(cfg, shape_kind: str, batch: int, seq: int) -> float:
    """Total FLOPs for one step of the given kind (global)."""
    f = sum(flops_forward(cfg, batch, seq).values())
    if shape_kind == "train":
        # fwd + bwd(2x) + remat re-fwd
        return f * (4.0 if cfg.remat else 3.0)
    if shape_kind == "prefill":
        return f
    # decode: one token against a cache
    fd = sum(flops_forward(cfg, batch, 1).values())
    # attention against the cache: 4*B*H*hd*S_cache per attn layer
    if cfg.n_heads:
        extra = 0.0
        for i in range(cfg.n_layers):
            if cfg.family == "ssm" or not cfg.is_attn_layer(i):
                continue
            w = cfg.layer_window(i)
            s_eff = min(seq, w) if w is not None else seq
            extra += 4.0 * batch * cfg.n_heads * cfg.d_head * s_eff
        fd += extra
    return fd


def hbm_bytes_step(cfg, shape_kind: str, batch: int, seq: int,
                   param_bytes_total: float, cache_bytes_total: float = 0.0,
                   opt_bytes_total: float = 0.0) -> float:
    """Approximate HBM traffic (global, all devices summed) for one step.

    train:   params fwd+bwd+update (3 reads + 2 writes) + opt states rw
             + activations (~14 residual-stream rw per layer, remat ~+50%)
             + attention score traffic
    prefill: params read + activations write/read once
    decode:  params read + full cache read + cache write (1 slot)
    """
    D = cfg.d_model
    T = batch * seq
    act_dtype = 2.0
    if shape_kind == "train":
        p = 3 * param_bytes_total + 2 * param_bytes_total
        p += 2 * opt_bytes_total
        act = 14.0 * cfg.n_layers * T * D * act_dtype
        if cfg.remat:
            act *= 1.5
        if cfg.n_heads:
            for i in range(cfg.n_layers):
                if cfg.family == "ssm" or not cfg.is_attn_layer(i):
                    continue
                w = cfg.layer_window(i)
                s_eff = min(seq, w) if w is not None else seq
                act += 6.0 * batch * cfg.n_heads * seq * s_eff * act_dtype
        return p + act
    if shape_kind == "prefill":
        act = 8.0 * cfg.n_layers * T * D * act_dtype
        if cfg.n_heads:
            for i in range(cfg.n_layers):
                if not cfg.is_attn_layer(i) or cfg.family == "ssm":
                    continue
                w = cfg.layer_window(i)
                s_eff = min(seq, w) if w is not None else seq
                act += 2.0 * batch * cfg.n_heads * seq * s_eff * act_dtype
        return param_bytes_total + act
    # decode
    return param_bytes_total + cache_bytes_total * 1.0 + \
        cache_bytes_total / max(seq, 1) + 8.0 * cfg.n_layers * batch * D * act_dtype


def model_flops_train(cfg, tokens: int) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) for one step."""
    n = cfg.param_count()
    if cfg.n_experts and cfg.top_k:
        # subtract inactive expert params
        d_ff_all = cfg.n_experts
        active_frac = cfg.top_k / cfg.n_experts
        # recompute: replace expert params with active fraction
        moe_layers = sum(1 for i in range(cfg.n_layers)
                         if cfg.is_moe_layer(i))
        per_layer_moe = cfg.n_experts * (3 if cfg.ffn_kind == "swiglu"
                                         else 2) * cfg.d_model * cfg.d_ff
        n = n - moe_layers * per_layer_moe * (1 - active_frac)
    return 6.0 * n * tokens


def model_flops_decode(cfg, new_tokens: int) -> float:
    """2*N_active per generated token (forward only)."""
    n = cfg.param_count()
    if cfg.n_experts and cfg.top_k:
        moe_layers = sum(1 for i in range(cfg.n_layers)
                         if cfg.is_moe_layer(i))
        per_layer_moe = cfg.n_experts * (3 if cfg.ffn_kind == "swiglu"
                                         else 2) * cfg.d_model * cfg.d_ff
        n = n - moe_layers * per_layer_moe * (1 - cfg.top_k / cfg.n_experts)
    return 2.0 * n * new_tokens
