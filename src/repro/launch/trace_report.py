"""Render a telemetry JSONL (the sibling of ``--trace out.trace.json``)
into a human-readable run report (DESIGN.md §14):

  PYTHONPATH=src python -m repro.launch.trace_report out.trace.jsonl
  PYTHONPATH=src python -m repro.launch.trace_report out.trace.jsonl --check

Three sections:

  1. step-time breakdown -- per-step wall / data-wait / mfu /
     comm_fraction aggregates over the run's step records;
  2. span table -- every span name with count / total / mean, straight
     from the tracer's span summary;
  3. roofline attribution -- the measured mean step time split into the
     analytic compute and collective terms of the run's
     ``StepCostModel`` (stamped into the meta header) plus the measured
     data-wait share, ending in a one-line verdict ("this run was 31%
     data-bound"): the Fig. 7 regime classification applied to a real
     trace instead of the analytic model.

``--check`` is the CI mode: exit non-zero unless the file has a meta
header and >= 1 step records whose mfu / comm_fraction / achieved_tflops
are all finite and sane (0 <= mfu <= 1, 0 <= comm_fraction <= 1).
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from typing import Any, Dict, List, Optional, Tuple


def load_records(path: str) -> List[Dict[str, Any]]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def split_records(recs: List[Dict[str, Any]]
                  ) -> Tuple[Dict, List[Dict], Dict, Dict, Dict, List[Dict]]:
    """(meta, steps, spans, counters, gauges, histograms)."""
    meta: Dict[str, Any] = {}
    steps: List[Dict[str, Any]] = []
    spans: Dict[str, Dict[str, float]] = {}
    counters: Dict[str, float] = {}
    gauges: Dict[str, float] = {}
    hists: List[Dict[str, Any]] = []
    for r in recs:
        kind = r.get("kind")
        if kind == "meta":
            meta.update({k: v for k, v in r.items() if k != "kind"})
        elif kind == "step":
            steps.append(r)
        elif kind == "spans":
            spans.update(r.get("spans", {}))
        elif kind == "counters":
            counters.update(r.get("counters", {}))
        elif kind == "gauges":
            gauges.update(r.get("gauges", {}))
        elif kind == "histogram":
            hists.append(r)
    return meta, steps, spans, counters, gauges, hists


def _mean(xs: List[float]) -> float:
    return sum(xs) / len(xs) if xs else float("nan")


def _pct(xs: List[float], p: float) -> float:
    if not xs:
        return float("nan")
    s = sorted(xs)
    return s[min(len(s) - 1, int(p * len(s)))]


def attribution(meta: Dict[str, Any], steps: List[Dict[str, Any]]
                ) -> Optional[Dict[str, float]]:
    """Mean-step-time shares: data / compute / collective / other.

    ``data`` is measured (the data_wait span -- time the consumer
    actually stalled on the input pipeline); compute and collective are
    the cost model's analytic roofline terms scaled by the step's
    rollout; ``other`` is whatever the model does not explain
    (dispatch, host overhead, py loop).  Shares are clamped to [0, 1]
    of the mean step time."""
    cm = meta.get("cost_model")
    if not cm or not steps:
        return None
    durs = [s["dur_s"] for s in steps if "dur_s" in s]
    waits = [s.get("data_wait_s", 0.0) for s in steps]
    rolls = [max(int(s.get("rollout", 1)), 1) for s in steps]
    if not durs:
        return None
    mean_dur = _mean(durs)
    mean_roll = _mean([float(r) for r in rolls])
    if not mean_dur or mean_dur <= 0:
        return None
    t_comp = cm.get("t_compute_s", 0.0) * mean_roll
    t_coll = cm.get("t_collective_s", 0.0) * mean_roll
    data = min(_mean(waits) / mean_dur, 1.0)
    compute = min(t_comp / mean_dur, 1.0)
    collective = min(t_coll / mean_dur, 1.0)
    other = max(0.0, 1.0 - data - compute - collective)
    return {"mean_step_s": mean_dur, "data": data, "compute": compute,
            "collective": collective, "other": other}


def verdict(att: Dict[str, float]) -> str:
    shares = {"data": att["data"], "compute": att["compute"],
              "comm": att["collective"], "overhead": att["other"]}
    name = max(shares, key=shares.get)
    return (f"this run was {shares[name] * 100:.0f}% {name}-bound "
            f"(data {att['data'] * 100:.0f}% / "
            f"compute {att['compute'] * 100:.0f}% / "
            f"comm {att['collective'] * 100:.0f}% / "
            f"other {att['other'] * 100:.0f}%)")


def check(meta: Dict[str, Any], steps: List[Dict[str, Any]]) -> List[str]:
    """CI assertions; returns a list of failures (empty = pass)."""
    fails: List[str] = []
    if not meta:
        fails.append("no meta header record")
    if not steps:
        fails.append("no step records")
    for s in steps:
        i = s.get("step", "?")
        for key, lo, hi in (("mfu", 0.0, 1.0),
                            ("comm_fraction", 0.0, 1.0),
                            ("achieved_tflops", 0.0, float("inf")),
                            ("dur_s", 0.0, float("inf"))):
            v = s.get(key)
            if v is None:
                fails.append(f"step {i}: missing {key}")
            elif not math.isfinite(v):
                fails.append(f"step {i}: {key}={v} not finite")
            elif not (lo <= v <= hi):
                fails.append(f"step {i}: {key}={v} outside [{lo}, {hi}]")
    return fails


def render(path: str, out=sys.stdout) -> None:
    meta, steps, spans, counters, gauges, hists = split_records(
        load_records(path))

    w = out.write
    w(f"== trace report: {path} ==\n")
    head = {k: meta[k] for k in ("arch", "mesh_model", "mesh_data",
                                 "scheme", "impl", "kernel", "precision",
                                 "batch", "rollout", "mode")
            if k in meta}
    if head:
        w("run: " + " ".join(f"{k}={v}" for k, v in head.items()) + "\n")

    if steps:
        durs = [s["dur_s"] for s in steps if "dur_s" in s]
        waits = [s.get("data_wait_s", 0.0) for s in steps]
        mfus = [s.get("mfu") for s in steps if s.get("mfu") is not None]
        comms = [s.get("comm_fraction") for s in steps
                 if s.get("comm_fraction") is not None]
        tf = [s.get("achieved_tflops") for s in steps
              if s.get("achieved_tflops") is not None]
        w(f"\n-- steps ({len(steps)}) --\n")
        w(f"{'metric':<18}{'mean':>12}{'p50':>12}{'p95':>12}\n")
        for name, xs, scale in (("step_s", durs, 1.0),
                                ("data_wait_s", waits, 1.0),
                                ("mfu", mfus, 1.0),
                                ("comm_fraction", comms, 1.0),
                                ("achieved_tflops", tf, 1.0)):
            if xs:
                w(f"{name:<18}{_mean(xs) * scale:>12.4g}"
                  f"{_pct(xs, 0.5) * scale:>12.4g}"
                  f"{_pct(xs, 0.95) * scale:>12.4g}\n")

    if spans:
        w(f"\n-- spans --\n")
        w(f"{'name':<24}{'count':>8}{'total_s':>12}{'mean_s':>12}\n")
        for name in sorted(spans, key=lambda n: -spans[n]["total_s"]):
            agg = spans[name]
            w(f"{name:<24}{agg['count']:>8}{agg['total_s']:>12.4g}"
              f"{agg['mean_s']:>12.4g}\n")

    if counters:
        w(f"\n-- counters --\n")
        for name in sorted(counters):
            w(f"{name:<32}{counters[name]:>16,.0f}\n")

    if hists:
        w(f"\n-- histograms --\n")
        w(f"{'name':<32}{'count':>8}{'p50':>12}{'p95':>12}{'p99':>12}\n")
        for h in hists:
            if not h.get("count"):
                continue
            w(f"{h['name']:<32}{h['count']:>8}{h.get('p50', 0):>12.4g}"
              f"{h.get('p95', 0):>12.4g}{h.get('p99', 0):>12.4g}\n")

    att = attribution(meta, steps)
    if att:
        w(f"\n-- roofline attribution --\n")
        w(f"mean step {att['mean_step_s'] * 1e3:.2f} ms = "
          f"data {att['data'] * 100:.1f}% + "
          f"compute {att['compute'] * 100:.1f}% + "
          f"comm {att['collective'] * 100:.1f}% + "
          f"other {att['other'] * 100:.1f}%\n")
        w(verdict(att) + "\n")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("jsonl", help="telemetry JSONL (the .jsonl sibling "
                                  "of --trace's Chrome JSON)")
    ap.add_argument("--check", action="store_true",
                    help="CI mode: exit 1 unless every step record has "
                         "finite, in-range mfu / comm_fraction / "
                         "achieved_tflops")
    args = ap.parse_args(argv)
    meta, steps, *_ = split_records(load_records(args.jsonl))
    if args.check:
        fails = check(meta, steps)
        if fails:
            for f in fails:
                print(f"[trace-check] FAIL: {f}")
            return 1
        print(f"[trace-check] OK: {len(steps)} step records, all "
              f"derived metrics finite and in range")
        return 0
    render(args.jsonl)
    return 0


if __name__ == "__main__":
    sys.exit(main())
