"""TrainEngine: the training loop as a small reusable subsystem.

Replaces the monolithic ``train()`` loop: the engine owns

  * mesh / sharding-rule resolution and the jitted step functions
    (one per rollout length, the paper's §6 randomized-rollout schedule),
  * the input pipeline (domain-parallel sharded reads + background
    prefetch, ``repro.data.pipeline``; ``sync-full`` preserves the legacy
    host-side full-batch generation for A/B runs),
  * microbatch gradient accumulation (``accum``),
  * eval cadence (held-out steps on a separate pipeline instance, so the
    prefetch thread and eval reads never share dataset memo state),
  * metrics history, logging, and zero-redundancy sharded checkpoints
    (async background writes, ``EngineConfig(resume=...)`` exact resume
    restoring params/opt/step/rollout-schedule/pipeline-cursor --
    DESIGN.md §9).

``launch/train.py``, the examples, and the measured benchmarks are thin
callers of this class (DESIGN.md §7).

Typical use:

    eng = TrainEngine("weathermixer-1b", mesh_model=4, mesh_data=2,
                      config=EngineConfig(steps=100, batch=8, rollout=3))
    history = eng.run()
    params = eng.params
"""
from __future__ import annotations

import dataclasses
import os
import time
from contextlib import nullcontext
from functools import partial
from typing import Callable, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro import compat
from repro import telemetry
from repro.configs.registry import get_config
from repro.core import precision
from repro.core.sharding import RULES_1D
from repro.data.pipeline import InputPipeline, make_pipeline
from repro.launch import shapes as SH
from repro.models import registry as M
from repro.optim import adam, schedule as sched
from repro.train.step import make_eval_step, make_train_step

# held-out validation stream: step indices far past any training step
EVAL_STEP_OFFSET = 1 << 20


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Step-dispatch policy of a TrainEngine (everything that is not the
    model / mesh itself)."""
    steps: int = 100
    batch: int = 8
    seq_len: int = 128
    rollout: int = 1           # randomized-rollout fine-tuning upper bound
    lr: float = 1e-3
    log_every: int = 10
    eval_every: int = 0        # 0 = no mid-training eval
    eval_batches: int = 2
    accum: int = 1             # microbatch gradient accumulation
    zero1: bool = False        # ZeRO-1: shard optimizer moments over data
    precision: Optional[str] = None   # policy preset (core/precision):
                               # fp32|bf16|bf16_pure; None = config dtypes
    ckpt: Optional[str] = None
    ckpt_every: int = 0        # 0 = only a final checkpoint (if ckpt set)
    keep_ckpts: int = 0        # keep last k periodic ckpts (0 = keep all)
    resume: Optional[str] = None   # checkpoint dir: exact-resume from it
    async_save: bool = True    # background checkpoint writes (DESIGN §9)
    seed: int = 0
    pipeline: str = "sharded"  # "sharded" | "sync-full"
    prefetch: int = 2          # 0 disables the background thread
    metrics_out: Optional[str] = None
    metrics_format: str = "jsonl"  # "jsonl" (crash-safe append, one
                               # line per record) | "json" (legacy full
                               # dump at the end of the run)
    telemetry: bool = True     # span/step-record tracing (DESIGN.md §14;
                               # counters stay live even when False)
    trace: Optional[str] = None    # Chrome trace-event export path; a
                               # sibling .jsonl gets the step records
    preemption: bool = False   # SIGTERM/SIGUSR1 -> final save + Preempted
    preempt_at_step: Optional[int] = None  # chaos hook: self-SIGTERM
                               # after this step (or REPRO_PREEMPT_AT_STEP)


class TrainEngine:
    """Owns params/opt state, the jitted steps, and the input pipeline."""

    def __init__(self, arch: str, *, reduced: bool = True,
                 mesh_model: int = 1, mesh_data: int = 1,
                 scheme: Optional[str] = None, impl: Optional[str] = None,
                 kernel: Optional[str] = None,
                 config: EngineConfig = EngineConfig(),
                 init_params=None, config_override=None):
        self.arch = arch
        self.config = config
        self.reduced = reduced
        if config.metrics_format not in ("jsonl", "json"):
            raise ValueError(
                f"unknown metrics_format {config.metrics_format!r} "
                f"(expected 'jsonl' or 'json')")
        cfg = config_override if config_override is not None \
            else get_config(arch)
        if reduced:
            cfg = cfg.reduced()
        if scheme:
            cfg = cfg.replace(scheme=scheme)
        if impl:
            cfg = cfg.replace(impl=impl)
        if kernel:
            cfg = cfg.replace(kernel=kernel)
        if config.precision:
            # precision policy (core/precision, DESIGN.md §10): one
            # replace threads param/compute dtypes into the config; the
            # JigsawConfig (ring wire/accum dtypes) and AdamConfig
            # (masters/moments) below are derived from the same policy
            cfg = precision.apply_policy(cfg, config.precision)
        self.policy = precision.policy_of(cfg)

        self.use_mesh = mesh_model * mesh_data > 1
        if self.use_mesh:
            from repro.launch.mesh import make_host_mesh
            self.mesh = make_host_mesh(model=mesh_model, data=mesh_data,
                                       two_d=cfg.scheme == "2d")
            self.rules = SH.rules_for(cfg)
        else:
            self.mesh = None
            cfg = cfg.replace(scheme="none")
            self.rules = RULES_1D
        self.cfg = cfg
        self.jcfg = SH.jigsaw_for(cfg).replace(rules=self.rules)
        self.mesh_model, self.mesh_data = mesh_model, mesh_data

        # telemetry (DESIGN.md §14): the engine owns the process tracer;
        # the pipeline / checkpoint writer / resilience hooks report
        # into it via telemetry.get_tracer().  The analytic cost model
        # turns each step's wall time into mfu / comm_fraction /
        # achieved_tflops (telemetry/accounting.py).
        self.tracer = telemetry.Tracer(enabled=config.telemetry)
        telemetry.set_tracer(self.tracer)
        self.cost_model = telemetry.build_cost_model(
            cfg, n_model=mesh_model, n_data=mesh_data,
            batch=config.batch, seq_len=config.seq_len)
        self.tracer.set_meta(
            arch=arch, reduced=reduced, mesh_model=mesh_model,
            mesh_data=mesh_data, scheme=cfg.scheme, impl=cfg.impl,
            kernel=cfg.kernel, precision=self.policy.name,
            steps=config.steps, batch=config.batch,
            rollout=config.rollout, zero1=config.zero1,
            cost_model=self.cost_model.as_meta())

        key = jax.random.PRNGKey(config.seed)
        # copy init_params: the step donates its buffers, and the caller
        # may still hold them (e.g. fig56 evaluates the base model after)
        self.params = M.init(key, cfg) if init_params is None \
            else jax.tree.map(jnp.copy, init_params)
        if init_params is not None and config.precision:
            # external params adopt the policy's storage dtype (masters
            # are re-derived fp32 from them in adam.init below)
            self.params = jax.tree.map(
                lambda p: p.astype(jnp.dtype(cfg.param_dtype))
                if jnp.issubdtype(p.dtype, jnp.floating) else p,
                self.params)
        pol = self.policy
        moment_dt = pol.moment_dtype
        self.adam_cfg = adam.AdamConfig(
            weight_decay=0.0, master_weights=pol.master_weights,
            state_dtype=None if moment_dt is None
            else jnp.dtype(moment_dt).name)
        # Engine-level param-spec pinning (ROADMAP PR-3 follow-up): pin
        # params to their jigsaw PartitionSpecs at init AND at every step
        # output, so non-zero1 runs no longer come back GSPMD-replicated
        # (which made sharded checkpoints dump all bytes on one rank).
        self._param_shardings = None
        if self.use_mesh:
            self._param_shardings = self._param_pins()
            self.params = jax.device_put(self.params,
                                         self._param_shardings)
        self.opt_state = adam.init(self.params, self.adam_cfg)
        # ZeRO-1 (ROADMAP PR-1 leftover, DESIGN.md §6.5): moments sharded
        # over the data axis; the step output is pinned to the same
        # layout so the sharding survives across updates, and GSPMD
        # allgathers only the fresh params (classic ZeRO-1 schedule).
        self._opt_shardings = None
        if config.zero1 and self.use_mesh:
            self._opt_shardings = self._zero1_shardings()
            self.opt_state = jax.device_put(self.opt_state,
                                            self._opt_shardings)
        self.lr_fn = partial(
            sched.warmup_cosine, base_lr=config.lr,
            warmup_steps=max(config.steps // 10, 1),
            total_steps=config.steps, min_lr=config.lr * 0.1)

        def _jit_step(r: int):
            fn = make_train_step(cfg, self.jcfg, adam_cfg=self.adam_cfg,
                                 lr_fn=self.lr_fn, rollout=r,
                                 accum=config.accum)
            psh, osh = self._param_shardings, self._opt_shardings
            if psh is not None or osh is not None:
                base = fn

                def fn(params, opt_state, batch):
                    p, o, m = base(params, opt_state, batch)
                    if psh is not None:
                        p = jax.tree.map(jax.lax.with_sharding_constraint,
                                         p, psh)
                    if osh is not None:
                        o = jax.tree.map(jax.lax.with_sharding_constraint,
                                         o, osh)
                    return p, o, m
            return jax.jit(fn, donate_argnums=(0, 1))

        # randomized-rollout fine-tuning (paper §6): each update draws a
        # rollout length r in [1, rollout]; one jitted step per r.
        self.step_fns = {r: _jit_step(r)
                         for r in range(1, config.rollout + 1)}
        r_rng = np.random.default_rng(config.seed + 1)
        self.r_sched = (
            r_rng.integers(1, config.rollout + 1, config.steps)
            if config.rollout > 1 else np.ones(config.steps, np.int64))

        self.pipeline = self._make_pipeline(config.pipeline,
                                            config.prefetch)
        self._eval_pipeline: Optional[InputPipeline] = None
        self._eval_fn = None
        self.history: List[Dict] = []
        self._metrics_flushed = 0   # history records already appended
        self.step_idx = 0
        # async sharded checkpointing (repro.checkpoint, DESIGN.md §9):
        # snapshot on this thread, stream files from a background one
        self._writer = ckpt.AsyncCheckpointWriter()
        self.last_save = None      # Snapshot of the most recent save
        self._ckpt_history: List[str] = []   # periodic dirs, oldest first
        self._prune_backlog: List[str] = []  # GC'd paths pending deletion
        self._stale_ckpt_error: Optional[BaseException] = None
        self.preempt_stats: Optional[Dict] = None  # final-save timing
        self.best_val = float("inf")
        self.best_ckpt: Optional[str] = None
        if config.resume:
            self._restore(config.resume)

    # -- construction helpers -------------------------------------------
    def _param_pins(self):
        """NamedShardings pinning every parameter to its jigsaw
        PartitionSpec (launch/specs.param_specs)."""
        from repro.launch import specs as S
        pspecs = S.param_specs(self.params, self.cfg, self.rules, self.mesh)
        pspecs = S.sanitize_tree(self.params, pspecs, self.mesh)
        return S.to_shardings(pspecs, self.mesh)

    def _zero1_shardings(self):
        """NamedShardings for the ZeRO-1 optimizer state: moments (and
        fp32 masters under the bf16 policy) inherit the param specs plus
        a data-axis shard on their first evenly divisible unsharded dim
        (launch/specs.opt_specs)."""
        from repro.launch import specs as S
        pspecs = S.param_specs(self.params, self.cfg, self.rules, self.mesh)
        pspecs = S.sanitize_tree(self.params, pspecs, self.mesh)
        ospecs = S.opt_specs(self.opt_state["mu"], pspecs,
                             zero1_axis=self.rules.batch_axes[-1],
                             mesh=self.mesh,
                             master="master" in self.opt_state)
        ospecs = S.sanitize_tree(self.opt_state, ospecs, self.mesh)
        return S.to_shardings(ospecs, self.mesh)

    def _make_pipeline(self, mode: str, prefetch: int) -> InputPipeline:
        return make_pipeline(self.cfg, mesh=self.mesh, rules=self.rules,
                             batch_size=self.config.batch,
                             seq_len=self.config.seq_len, mode=mode,
                             prefetch=prefetch, seed=self.config.seed)

    def _mesh_ctx(self):
        return compat.set_mesh(self.mesh) if self.use_mesh \
            else nullcontext()

    # -- single dispatch -------------------------------------------------
    def dispatch(self, batch, rollout_len: int = 1) -> Dict[str, float]:
        """Run one update on ``batch``; returns raw device metrics."""
        self.params, self.opt_state, metrics = \
            self.step_fns[rollout_len](self.params, self.opt_state, batch)
        self.step_idx += 1
        return metrics

    # -- the loop --------------------------------------------------------
    def run(self, on_step: Optional[Callable[[int, Dict], None]] = None
            ) -> List[Dict]:
        """Train for ``config.steps`` steps; returns the metrics history
        (same record format as the legacy train() loop).

        With ``config.preemption`` (or the ``preempt_at_step`` chaos
        hook) a SIGTERM/SIGUSR1 lets the in-flight step complete, then
        takes a final SYNCHRONOUS checkpoint and raises
        :class:`repro.launch.resilience.Preempted` -- the orderly-exit
        half of the DESIGN.md §12 preemption choreography."""
        from repro.launch import resilience
        c = self.config
        start = self.step_idx          # > 0 after a resume
        handler = None
        if c.preemption or c.preempt_at_step is not None:
            handler = resilience.PreemptionHandler(
                preempt_at_step=c.preempt_at_step).install()
        tr = self.tracer
        try:
            with self._mesh_ctx():
                t0 = time.time()
                it = iter(self.pipeline.iterate(self.r_sched[start:],
                                                start_step=start))
                t_prev = time.perf_counter()
                for i in range(start, c.steps):
                    # data_wait: time the loop spends blocked on the
                    # input pipeline (0 when prefetch is ahead)
                    with tr.span("data_wait", step=i) as dw:
                        try:
                            batch = next(it)
                        except StopIteration:
                            break
                    r = int(self.r_sched[i])
                    # "step" is the PARENT span of everything this
                    # iteration does after the batch arrives: dispatch,
                    # eval, ckpt_submit nest under it in the trace
                    with tr.span("step", step=i, rollout=r):
                        with tr.span("dispatch", step=i):
                            metrics = self.dispatch(batch, r)
                        # per-step wall time = submit-to-submit delta:
                        # jax dispatch is async, so the device time of
                        # step i surfaces as backpressure on iteration
                        # i+1; the deltas sum to true wall time without
                        # forcing a per-step sync (which would serialize
                        # the overlap this repo exists to measure)
                        now = time.perf_counter()
                        wall, t_prev = now - t_prev, now
                        tr.step_record(
                            step=i, rollout=r, dur_s=wall,
                            data_wait_s=dw.dur_s,
                            **self.cost_model.metrics(wall, rollout=r))
                        if i % c.log_every == 0 or i == c.steps - 1:
                            m = {k: float(v) for k, v in metrics.items()}
                            m["step"] = i
                            m["wall_s"] = round(time.time() - t0, 1)
                            self.history.append(m)
                            self._write_metrics()
                            print(f"step {i:5d}  loss {m['loss']:.4f}  "
                                  f"lr {m['lr']:.2e}  ({m['wall_s']}s)")
                        pending_val = None
                        if c.eval_every and i and i % c.eval_every == 0:
                            with tr.span("eval", step=i):
                                em = self.evaluate()
                            self.history.append(dict(em, step=i,
                                                     eval=True))
                            self._write_metrics()
                            print(f"step {i:5d}  "
                                  f"val_loss {em['val_loss']:.4f}")
                            pending_val = em["val_loss"]
                        if on_step is not None:
                            on_step(i, metrics)
                        if c.ckpt and c.ckpt_every and i \
                                and i % c.ckpt_every == 0:
                            self.save(f"{c.ckpt}-{i}", periodic=True)
                        if pending_val is not None:
                            # after the save: when eval and ckpt
                            # cadences align, the marker points at THIS
                            # step's checkpoint, not the previous one
                            self._mark_best(pending_val)
                    if handler is not None and handler.poll(i):
                        self._preempt_finalize(i, handler)
            if c.ckpt:
                self.save(c.ckpt)
                print(f"checkpoint -> {c.ckpt}")
            self.wait_checkpoints()    # barrier for in-flight writes
            self._write_metrics(final=True)
            self._export_telemetry()
            return self.history
        finally:
            if handler is not None:
                handler.uninstall()

    def _preempt_finalize(self, i: int, handler) -> None:
        """Orderly preemption exit: the step that was in flight has
        completed.  Stop the prefetch thread, drain (and absorb) any
        pending async-write error, take a final SYNCHRONOUS checkpoint,
        persist the metrics history, and raise ``Preempted`` for
        ``launch/train.py`` to translate into the resumable exit code."""
        from repro.launch import resilience
        c = self.config
        sig = handler.received
        self.tracer.event("preempt.signal", signum=sig, step=i)
        print(f"[preempt] signal {sig} after step {i}: "
              f"final synchronous save, then resumable exit")
        self.pipeline.stop()
        try:
            self.wait_checkpoints()
        except Exception as e:
            # an EARLIER async write failed; its prune list is still in
            # _prune_backlog (re-queued by the next save) -- it must not
            # abort the final preemption save, which may become the only
            # durable copy of this run segment
            print(f"[preempt] pending async save had failed: {e!r}; "
                  f"final save proceeds")
        path = None
        if c.ckpt:
            path = f"{c.ckpt}-{i}"
            if self._ckpt_history and self._ckpt_history[-1] == path:
                # the periodic cadence saved this very step already
                pass
            else:
                t0 = time.time()
                self.save(path, block=True, periodic=True)
                self.preempt_stats = {"step": i,
                                      "final_save_s": time.time() - t0}
                self.tracer.event("preempt.final_save", step=i,
                                  dur_s=self.preempt_stats["final_save_s"],
                                  path=path)
            print(f"[preempt] checkpoint durable -> {path}")
        self._write_metrics(final=True)
        # flush the trace BEFORE raising: the Preempted exit is exactly
        # when the operator needs to see where the run's time went
        self._export_telemetry()
        raise resilience.Preempted(step=self.step_idx, checkpoint=path,
                                   signum=sig)

    def _write_metrics(self, final: bool = False) -> None:
        """Persist the metrics history.

        Default ``metrics_format="jsonl"``: crash-safe APPEND of the
        records added since the last flush, one JSON object per line --
        called at every log/eval cadence, so a kill -9 loses at most one
        flush window and never tears the file, and the cost per call is
        O(new records), not O(run length).  ``"json"`` keeps the legacy
        whole-history dump, written only when ``final`` (run end /
        preemption) -- rewriting it per flush would be O(n^2) over a
        long run and a torn file if killed mid-dump."""
        if not self.config.metrics_out:
            return
        import json
        if self.config.metrics_format == "json":
            if final:
                with open(self.config.metrics_out, "w") as f:
                    json.dump(self.history, f, indent=1)
            return
        new = self.history[self._metrics_flushed:]
        if not new:
            return
        with open(self.config.metrics_out, "a") as f:
            for rec in new:
                f.write(json.dumps(rec) + "\n")
        self._metrics_flushed = len(self.history)

    def _export_telemetry(self) -> None:
        """Write the Chrome trace (+ sibling step-record JSONL) when
        ``config.trace`` is set.  Called at run end AND on the
        preemption path, so a reclaimed node still leaves its trace."""
        c = self.config
        if not c.trace:
            return
        self.tracer.export_chrome(c.trace)
        jsonl = telemetry.jsonl_path_for(c.trace)
        self.tracer.export_jsonl(jsonl)
        print(f"trace -> {c.trace} (+ {jsonl})")

    # -- evaluation ------------------------------------------------------
    def evaluate(self, n_batches: Optional[int] = None) -> Dict[str, float]:
        """Mean metrics over held-out batches (step indices offset past
        the training stream; separate pipeline instance so prefetch and
        eval never share memo state)."""
        n = n_batches or self.config.eval_batches
        if self._eval_pipeline is None:
            self._eval_pipeline = self._make_pipeline(
                self.config.pipeline, prefetch=0)
            self._eval_fn = jax.jit(make_eval_step(self.cfg, self.jcfg))
        vals: Dict[str, List[float]] = {}
        with self._mesh_ctx():
            for j in range(n):
                b = self._eval_pipeline.get(EVAL_STEP_OFFSET + j)
                for k, v in self._eval_fn(self.params, b).items():
                    vals.setdefault(k, []).append(float(v))
        out = {f"val_{k}": float(np.mean(v)) for k, v in vals.items()}
        return out

    # -- checkpointing ---------------------------------------------------
    def save(self, path: str, block: Optional[bool] = None,
             periodic: bool = False) -> None:
        """Sharded checkpoint of params/opt_state/step + resume state.

        Each rank serializes only its addressable shards (no full-model
        gather); with ``config.async_save`` the device->host snapshot
        happens here and the file writes stream from a background thread
        while training continues (``wait_checkpoints`` is the barrier).

        ``periodic=True`` registers the path for keep-last-k GC
        (``EngineConfig(keep_ckpts=k)``): once more than k periodic
        checkpoints exist, the oldest are deleted -- except the one the
        ``best`` marker points at.  The GC list is handed to the writer,
        which prunes only AFTER the new checkpoint is fully on disk."""
        c = self.config
        block = (not c.async_save) if block is None else block
        prune = []
        if periodic:
            self._ckpt_history.append(path)
            if c.keep_ckpts > 0:
                keep = set(self._ckpt_history[-c.keep_ckpts:])
                if self.best_ckpt:
                    keep.add(self.best_ckpt)
                prune = [p for p in self._ckpt_history if p not in keep]
                self._ckpt_history = [p for p in self._ckpt_history
                                      if p not in prune]
                # re-queue paths whose earlier prune never ran (a failed
                # async write skips its prune) so GC'd dirs cannot leak
                prune += [p for p in self._prune_backlog
                          if p not in prune and p not in keep
                          and os.path.isdir(p)]
        else:
            # final / preemption saves drain the backlog too: this may
            # be the run's last save, so an orphaned prune list would
            # leak GC'd directories forever
            prune = [p for p in self._prune_backlog if os.path.isdir(p)]
        self._prune_backlog = prune
        extra = {"arch": self.arch, "reduced": self.reduced,
                 "seed": c.seed, "steps": c.steps, "rollout": c.rollout,
                 "scheme": self.cfg.scheme,
                 "precision": self.policy.name,
                 "pipeline": self.pipeline.state(),
                 # GC/best state survives a resume: without it a restarted
                 # run would re-mark a worse best and never prune the
                 # pre-resume periodic checkpoints
                 "best": {"val": (None if self.best_val == float("inf")
                                  else self.best_val),
                          "ckpt": self.best_ckpt},
                 "ckpt_history": list(self._ckpt_history),
                 # prune list persisted with the save: if this process
                 # dies before the deletions run, the resumed run
                 # re-queues them instead of orphaning the GC state
                 "prune_backlog": list(self._prune_backlog)}
        try:
            self._writer.wait()
        except Exception as e:
            # a FAILED earlier async write surfaces at the writer's
            # in-flight guard.  It must not abort THIS save (a final
            # preemption save may be the last durable copy of the run);
            # its prune list stays queued in _prune_backlog, and the
            # error is re-raised at the next wait_checkpoints() barrier.
            print(f"[ckpt] earlier async checkpoint write failed: {e!r}; "
                  f"proceeding with save of {path!r}")
            self._stale_ckpt_error = e
        # ckpt_submit covers the synchronous part the train loop pays
        # for: the device->host snapshot (plus, under block=True, the
        # whole write); the background streaming shows up as ckpt.write
        # spans on the writer thread's own track
        with self.tracer.span("ckpt_submit", path=path, block=block,
                              step=self.step_idx):
            self.last_save = self._writer.save(
                path, {"params": self.params,
                       "opt_state": self.opt_state},
                step=self.step_idx, extra=extra, mesh=self.mesh,
                block=block, prune=prune,
                process_index=jax.process_index(),
                process_count=jax.process_count())

    def _mark_best(self, val_loss: float) -> None:
        """Track the best eval loss; point the ``<ckpt>-best.json`` marker
        at the newest periodic checkpoint at-or-before the eval when it
        improves.  The marker is honest about the misaligned-cadence case:
        ``eval_step``/``val_loss`` describe the weights that were
        evaluated, ``ckpt_step`` the (possibly earlier) checkpoint the
        path refers to."""
        if val_loss >= self.best_val:
            return
        self.best_val = float(val_loss)
        if not (self.config.ckpt and self._ckpt_history):
            return
        self.best_ckpt = self._ckpt_history[-1]
        suffix = self.best_ckpt.rsplit("-", 1)[-1]
        import json
        marker = {"path": self.best_ckpt, "val_loss": self.best_val,
                  "eval_step": self.step_idx,
                  "ckpt_step": int(suffix) if suffix.isdigit() else None}
        with open(f"{self.config.ckpt}-best.json", "w") as f:
            json.dump(marker, f, indent=1)

    def wait_checkpoints(self) -> None:
        """Barrier for in-flight checkpoint writes (re-raises their
        errors on this thread) -- including an absorbed error from an
        earlier failed write that ``save`` proceeded past."""
        self._writer.wait()
        if self._stale_ckpt_error is not None:
            err, self._stale_ckpt_error = self._stale_ckpt_error, None
            raise err

    def _restore(self, path: str) -> None:
        """Exact resume: params, opt state (incl. Adam step), loop step
        index, rollout schedule (revalidated from config), and the data
        pipeline cursor -- an interrupted run continues with a
        bit-identical loss history (``resume_exact`` dist scenario).

        The restore is ELASTIC (DESIGN.md §12): the checkpoint may have
        been written on a different mesh shape.  Every leaf is
        reassembled from the manifest's global index bounds against THIS
        engine's own param / ZeRO-1 layouts (``specs=`` override below),
        so moments and fp32 masters land sharded over the current data
        axis even when the saved topology -- and hence the saved specs'
        divisibility choices -- differ (``elastic_reshard_resume``
        scenario).  The data pipeline needs no refit: its read plans are
        derived from the current mesh at construction, only the cursor
        is restored."""
        c = self.config
        man = ckpt.load_manifest(path)
        for field in ("seed", "rollout", "steps"):
            want, got = getattr(c, field), man.extra.get(field)
            if got is not None and got != want:
                raise ValueError(
                    f"resume {path!r}: checkpoint {field}={got} != engine "
                    f"{field}={want} -- the rollout schedule / lr "
                    f"schedule would diverge; pass the saved value")
        arch = man.extra.get("arch")
        if arch is not None and arch != self.arch:
            raise ValueError(f"resume {path!r}: checkpoint arch {arch!r} "
                             f"!= engine arch {self.arch!r}")
        prec = man.extra.get("precision")
        if prec is not None and prec != self.policy.name:
            hint = ("omit --precision (the checkpoint predates the "
                    "policy presets)" if prec == "legacy"
                    else f"pass --precision {prec}")
            raise ValueError(
                f"resume {path!r}: checkpoint precision {prec!r} != engine "
                f"policy {self.policy.name!r} -- param dtypes and the "
                f"master-weight state would not line up; {hint}")
        cur_shape = (None if self.mesh is None
                     else tuple(self.mesh.devices.shape))
        if (man.mesh_shape is not None and cur_shape is not None
                and tuple(man.mesh_shape) != cur_shape):
            print(f"[resume] elastic reshard: checkpoint mesh "
                  f"{tuple(man.mesh_shape)} -> current mesh {cur_shape}")
        pspecs = ospecs = None
        if self._param_shardings is not None:
            pspecs = jax.tree.map(lambda s: s.spec, self._param_shardings)
        if self._opt_shardings is not None:
            ospecs = jax.tree.map(lambda s: s.spec, self._opt_shardings)
        params = ckpt.restore_tree(path, "params", like=self.params,
                                   mesh=self.mesh, specs=pspecs)
        opt = ckpt.restore_tree(path, "opt_state", like=self.opt_state,
                                mesh=self.mesh, specs=ospecs)
        if self.mesh is None:
            params = jax.tree.map(jnp.asarray, params)
            opt = jax.tree.map(jnp.asarray, opt)
        self.params, self.opt_state = params, opt
        self.step_idx = man.step
        self.pipeline.set_state(man.extra.get("pipeline",
                                              {"cursor": man.step}))
        # best-marker state: the synchronously-written <ckpt>-best.json is
        # authoritative (the manifest's copy can be one eval stale when
        # the eval and ckpt cadences align); manifest extra is the
        # fallback when this run has no --ckpt or the marker is gone
        best = man.extra.get("best") or {}
        marker_file = f"{c.ckpt}-best.json" if c.ckpt else None
        if marker_file and os.path.exists(marker_file):
            import json
            with open(marker_file) as f:
                m = json.load(f)
            best = {"val": m.get("val_loss"), "ckpt": m.get("path")}
        if best.get("val") is not None:
            self.best_val = float(best["val"])
            self.best_ckpt = best.get("ckpt")
        self._ckpt_history = [p for p in man.extra.get("ckpt_history", [])
                              if os.path.isdir(p)]
        # deletions the dead process never ran: re-queued at the next save
        self._prune_backlog = [
            p for p in man.extra.get("prune_backlog", [])
            if os.path.isdir(p)]

    # -- benchmarking ----------------------------------------------------
    def benchmark(self, steps: int = 10, warmup: int = 2) -> float:
        """Steady-state seconds per training step (compile + warmup
        excluded), through the engine's own pipeline -- used by the
        measured scaling and pipeline-overlap benchmarks."""
        horizons = np.ones(warmup + steps, np.int64)
        with self._mesh_ctx():
            it = self.pipeline.iterate(horizons)
            for j, batch in enumerate(it):
                if j == warmup:
                    jax.block_until_ready(jax.tree.leaves(self.params)[0])
                    t0 = time.time()
                self.dispatch(batch, 1)
            jax.block_until_ready(jax.tree.leaves(self.params)[0])
        return (time.time() - t0) / steps
