"""PartitionSpec derivation for parameters, batches, caches, optimizer.

The rules encode Jigsaw's zero-redundancy layout (DESIGN.md §5):

* every weight matrix ``w`` is sharded along its contracting (last) dim on
  the ``model`` axis (1-D Jigsaw) or over (out x in) = (mtp x mdom) for the
  2-D/Cannon scheme -- WeatherMixer token-mix weights use the transposed
  (mdom x mtp) Cannon layout;
* biases ride the output dim (tp axis);
* MoE expert stacks shard the expert dim on ``model`` (expert parallelism);
* very large archs additionally shard the output dim over ``data``
  (``shard_params_over_data`` -- the FSDP-hybrid extension of n-way Jigsaw);
* optimizer moments inherit the parameter specs exactly (zero redundancy
  of optimizer state, paper §4);
* KV caches shard heads on ``model`` when divisible, else the sequence dim
  (flash-decoding-style); batch always on ``data`` (+``pod``).

Any spec dim that does not divide its mesh axis extent falls back to
GSPMD's padded sharding (allowed for jit boundaries), except where noted.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.sharding import ShardingRules

# parameter leaf names that are always replicated
_REPLICATED = {"scale", "bias", "A_log", "D", "dt_bias", "blend"}
_TOKEN_MIX = {"tok_fc1", "tok_fc2"}     # WeatherMixer transposed layout


def _axis(mesh: Mesh, name: Optional[str]) -> int:
    return mesh.shape.get(name, 1) if name else 1


def param_specs(params, cfg: ModelConfig, rules: ShardingRules,
                mesh: Mesh):
    """PartitionSpec pytree matching ``params``."""
    tp = rules.tp_axis
    dom = rules.dom_axis
    data = rules.batch_axes[-1] if cfg.shard_params_over_data else None

    def spec_for(path: Tuple[str, ...], leaf) -> P:
        name = path[-1]
        parent = path[-2] if len(path) > 1 else ""
        nd = leaf.ndim
        dims = [None] * nd
        if name in _REPLICATED or parent == "router" or name == "pos":
            return P(*dims)
        if rules.is_2d:
            # --- 2-D Jigsaw (WeatherMixer) ---
            if name == "w":
                if parent in _TOKEN_MIX:
                    dims[nd - 2], dims[nd - 1] = dom, tp   # Cannon W@X
                else:
                    dims[nd - 2], dims[nd - 1] = tp, dom   # Cannon X@W^T
            elif name == "b":
                dims[nd - 1] = dom if parent in _TOKEN_MIX else tp
            elif name == "table":
                dims[nd - 1] = tp
            return P(*dims)
        # --- 1-D Jigsaw ---
        if name == "w":
            if parent == "lm_head":
                # head weights [V, D] shard the OUT (vocab) dim, like the
                # tied table: contracting-dim sharding makes GSPMD emit
                # full-vocab f32 partials + allreduce (~96 GiB at
                # pixtral train_4k).  See EXPERIMENTS.md #Perf C2.
                dims[nd - 2] = tp
                if data:
                    dims[nd - 1] = data
                return P(*dims)
            dims[nd - 1] = tp          # contracting dim: zero redundancy
            if data and nd >= 2:
                dims[nd - 2] = data    # FSDP-hybrid for huge archs
        elif name == "b":
            dims[nd - 1] = tp
        elif name == "table":
            # vocab on tp: the embedding gather pays one [B,S,D] psum,
            # but the (tied) LM head then contracts the *replicated* D dim
            # and emits vocab-sharded logits -- sharding D instead makes
            # GSPMD materialize full-vocab f32 partials (~22 GiB/device).
            dims[nd - 2] = tp
            if data:
                dims[nd - 1] = data
        elif name == "dec_pos":
            dims[nd - 1] = tp
        elif name == "conv_w":
            dims[nd - 1] = tp
        elif parent == "experts":
            # [(L,) E, F, D] / [(L,) E, D, F]: experts on model axis
            dims[nd - 3] = tp
            if data:
                dims[nd - 2] = data
        return P(*dims)

    def walk(tree, path=()):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        return spec_for(path, tree)

    return walk(params)


def opt_specs(moments, pspecs, zero1_axis: Optional[str] = None,
              mesh: Optional[Mesh] = None, master: bool = False):
    """Optimizer moments inherit parameter specs; step is replicated.
    ``master=True`` adds the fp32 master-weight group (precision policy
    ``bf16``, DESIGN.md §10), sharded exactly like the moments.

    ``moments`` is the parameter-shaped tree the moment specs are derived
    for (arrays or ShapeDtypeStructs -- only ``.shape`` is read, and only
    when ``mesh`` is given; ``None`` keeps the shape-agnostic choice).

    ``zero1_axis`` (beyond-paper, DESIGN.md §6.5): additionally shard
    every moment over the data axis on its first unsharded dim --
    ZeRO-1.  The Adam update then computes per-data-rank shards and
    GSPMD allgathers the fresh params (the classic ZeRO-1 schedule),
    cutting optimizer HBM by the data-axis extent.  With ``mesh`` the
    choice is shape-aware: dims the axis extent does not divide are
    skipped (a stacked [n_layers, m, d] leaf shards its m dim, not the
    tiny layer dim that sanitize_tree would only drop again).
    """
    extent = mesh.shape[zero1_axis] if (mesh is not None and zero1_axis) \
        else None

    def z1(spec: P, shape=None) -> P:
        if zero1_axis is None:
            return spec
        dims = list(spec)
        if shape is not None:
            dims += [None] * (len(shape) - len(dims))
        used = set()
        for e in dims:
            if e is not None:
                used |= set(e) if isinstance(e, tuple) else {e}
        if zero1_axis in used:
            return P(*dims)
        for i, entry in enumerate(dims):
            if entry is not None:
                continue
            if shape is not None and extent is not None \
                    and shape[i] % extent != 0:
                continue
            dims[i] = zero1_axis
            break
        return P(*dims)

    if moments is None:
        mspecs = jax.tree.map(z1, pspecs,
                              is_leaf=lambda x: isinstance(x, P))
    else:
        mspecs = jax.tree.map(lambda leaf, sp: z1(sp, leaf.shape),
                              moments, pspecs)
    out = {"step": P(), "mu": mspecs, "nu": mspecs}
    if master:
        out["master"] = mspecs
    return out


def batch_specs(cfg: ModelConfig, rules: ShardingRules):
    """Input batch specs: batch dim over (pod+) data."""
    bspec = rules.batch_axes
    if cfg.family == "mixer":
        # domain parallelism over (lon, channels): the sample itself is
        # sharded -- each rank loads only its slice (paper §5).
        if rules.is_2d:
            fields = P(bspec, None, rules.dom_axis, rules.tp_axis)
        else:
            fields = P(bspec, None, None, rules.tp_axis)
        return {"fields": fields, "target": fields}
    specs = {"tokens": P(bspec, None), "labels": P(bspec, None)}
    if cfg.family == "vlm":
        specs["embeds"] = P(bspec, None, rules.tp_axis)
    if cfg.family == "audio":
        specs["frames"] = P(bspec, None, rules.tp_axis)
    return specs


def cache_specs(cache, cfg: ModelConfig, rules: ShardingRules, mesh: Mesh):
    """KV/SSM cache specs for decode shapes."""
    tp = rules.tp_axis
    data = rules.batch_axes
    p = _axis(mesh, tp)
    kv_even = cfg.n_kv_heads > 0 and cfg.n_kv_heads % p == 0
    ssm_even = cfg.ssm_heads > 0 and cfg.ssm_heads % p == 0

    def spec_for(path, leaf):
        name = path[-1]
        nd = leaf.ndim
        dims = [None] * nd
        if name == "pos":
            return P(*dims)
        if name in ("k", "v", "lk", "lv", "gk", "gv", "rk", "rv"):
            # [..., B, S, Hkv, hd]
            dims[nd - 4] = data
            mode = getattr(cfg, "kv_shard", "auto")
            if mode == "auto":
                mode = "heads" if kv_even else "seq"
            if mode == "heads":
                dims[nd - 2] = tp          # shard heads
            elif mode == "headdim":
                dims[nd - 1] = tp          # shard head_dim (GQA kv < tp)
            else:
                dims[nd - 3] = tp          # shard sequence (flash-decoding)
            return P(*dims)
        if name == "ssm":
            # [..., B, H, P, N]
            dims[nd - 4] = data
            if ssm_even:
                dims[nd - 3] = tp
            return P(*dims)
        if name == "conv":
            # [..., B, K-1, conv_dim]
            dims[nd - 3] = data
            dims[nd - 1] = tp
            return P(*dims)
        if name == "enc":
            # [B, frames, d_model]
            dims[0] = data
            dims[nd - 1] = tp
            return P(*dims)
        return P(*dims)

    def walk(tree, path=()):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        return spec_for(path, tree)

    return walk(cache)


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def sanitize_spec(shape: Tuple[int, ...], spec: P, mesh: Mesh) -> P:
    """Drop spec entries whose mesh extent does not evenly divide the
    corresponding dim (jit input shardings require even division; e.g.
    long_500k's global_batch=1 cannot shard over data=16, and 8 KV heads
    cannot shard over model=16 -- those dims replicate instead)."""
    dims = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for size, entry in zip(shape, dims):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        extent = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(entry if size % extent == 0 else None)
    return P(*out)


def sanitize_tree(shapes_tree, spec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s, sp: sanitize_spec(s.shape, sp, mesh), shapes_tree,
        spec_tree, is_leaf=lambda x: isinstance(x, P))
