import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: prove every (arch x shape x mesh) lowers+compiles.

The two lines above MUST run before any other import (jax locks the
device count on first init).  512 placeholder host devices cover both the
single-pod (16x16) and multi-pod (2x16x16) production meshes.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b \
      --shape train_4k [--multi-pod] [--scheme 1d] [--impl rs] \
      [--out results.jsonl]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--out results.jsonl]

For each combination it prints ``memory_analysis()`` (the fits-in-HBM
proof) and the roofline terms (analysis.py), and appends a JSON record.
"""
import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_config
from repro.launch import analysis as A
from repro.launch import shapes as SH
from repro.launch.mesh import (make_production_mesh, make_production_mesh_2d)
from repro.models import registry as M
from repro.optim import adam
from repro.serve.step import make_serve_step
from repro.train.step import make_train_step


def build_step_and_args(cfg, shape, mesh, rules, jcfg, zero1=False):
    """Returns (fn, args tuple of ShapeDtypeStructs)."""
    if shape.kind == "train":
        pstructs, pspecs = SH.param_structs(cfg, mesh, rules)
        acfg = adam.AdamConfig(state_dtype=cfg.param_dtype)
        ostructs, _ = SH.opt_structs(pstructs, pspecs, cfg, mesh, acfg,
                                     zero1=zero1)
        batch = SH.input_specs(cfg, shape, mesh, rules)
        return make_train_step(cfg, jcfg, adam_cfg=acfg), \
            (pstructs, ostructs, batch)
    if shape.kind == "prefill":
        pstructs, _ = SH.param_structs(cfg, mesh, rules)
        batch = SH.input_specs(cfg, shape, mesh, rules)

        def prefill_step(params, b):
            if cfg.family == "mixer":
                out, _ = M.apply(params, b, cfg, jcfg)
                return out
            logits, _ = M.apply(params, b, cfg, jcfg)
            return jnp.argmax(logits[:, -1], axis=-1)

        return prefill_step, (pstructs, batch)
    # decode
    pstructs, _ = SH.param_structs(cfg, mesh, rules)
    cstructs, _ = SH.cache_structs(cfg, shape, mesh, rules)
    batch = SH.input_specs(cfg, shape, mesh, rules)
    return make_serve_step(cfg, jcfg), (pstructs, cstructs, batch["tokens"])


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            scheme=None, impl=None, remat=None, q_chunk=None,
            kv_shard=None, zero1: bool = False, verbose: bool = True):
    cfg = get_config(arch)
    if scheme:
        cfg = cfg.replace(scheme=scheme)
    if impl:
        cfg = cfg.replace(impl=impl)
    if remat is not None:
        cfg = cfg.replace(remat=remat)
    if q_chunk is not None:
        cfg = cfg.replace(attn_q_chunk=q_chunk)
    if kv_shard is not None:
        cfg = cfg.replace(kv_shard=kv_shard)
    shape = SH.SHAPES[shape_name]
    if cfg.family == "mixer":
        # WM token-mix weights are [d_tok, T]: the model is tied to its
        # grid, so each input shape instantiates the arch AT that grid
        # (train_4k: 512x512 = 4096 tokens; prefill_32k: 1456x1440 ~= the
        # paper's own 0.25-degree resolution).
        lat, lon = SH.mixer_grid_for(shape, cfg)
        cfg = cfg.replace(wm_lat=lat, wm_lon=lon)
    ok, reason = SH.applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "scheme": cfg.scheme,
           "impl": cfg.impl, "multi_pod": multi_pod, "zero1": zero1,
           "q_chunk": cfg.attn_q_chunk, "kv_shard": cfg.kv_shard}
    if not ok:
        rec.update(status="SKIP", reason=reason)
        if verbose:
            print(f"[skip] {arch} x {shape_name}: {reason}")
        return rec

    mesh = (make_production_mesh_2d(multi_pod=multi_pod)
            if cfg.scheme == "2d"
            else make_production_mesh(multi_pod=multi_pod))
    rules = SH.rules_for(cfg)
    if multi_pod:
        import dataclasses as dc
        rules = dc.replace(rules, batch_axes=("pod",) + rules.batch_axes)
    jcfg = SH.jigsaw_for(cfg).replace(rules=rules)
    n_dev = mesh.size
    t0 = time.time()
    try:
        with jax.set_mesh(mesh):
            fn, args = build_step_and_args(cfg, shape, mesh, rules, jcfg,
                                           zero1=zero1)
            lowered = jax.jit(fn).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
    except Exception as e:
        rec.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[FAIL] {arch} x {shape_name} "
                  f"({'multi' if multi_pod else 'single'}-pod): {e}")
        return rec

    ma = compiled.memory_analysis()
    print(f"# {arch} x {shape_name} "
          f"({'2x16x16' if multi_pod else '16x16'}, scheme={cfg.scheme}, "
          f"impl={cfg.impl})")
    print(f"  memory_analysis: {ma}")

    # roofline terms
    param_bytes = tree_bytes(args[0])
    opt_bytes = tree_bytes(args[1]) if shape.kind == "train" else 0
    cache_bytes = tree_bytes(args[1]) if shape.kind == "decode" else 0
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "mixer":
        lat, lon = SH.mixer_grid_for(shape, cfg)
        s = (lat // cfg.wm_patch) * (lon // cfg.wm_patch)
    flops_total = A.flops_step(cfg, shape.kind, b, s)
    hbm_total = A.hbm_bytes_step(cfg, shape.kind, b, s, param_bytes,
                                 cache_bytes, opt_bytes)
    stats = A.collective_stats(compiled.as_text())
    ca = compiled.cost_analysis() or {}
    tokens = b * (s if shape.kind != "decode" else 1)
    if cfg.family == "mixer":
        # 6*N*D is a dense-LM heuristic; WM's token-mix params scale with
        # T, so MODEL_FLOPS is the forward matmul work itself (x3 for
        # train fwd+bwd) -- useful_ratio then exposes the remat factor.
        fwd = sum(A.flops_forward(cfg, b, s).values())
        mf = 3.0 * fwd if shape.kind == "train" else fwd
    else:
        mf = (A.model_flops_train(cfg, tokens) if shape.kind == "train"
              else A.model_flops_decode(cfg, b) if shape.kind == "decode"
              else A.model_flops_train(cfg, tokens) / 3.0)
    comp_s = flops_total / n_dev / A.PEAK_FLOPS_BF16
    mem_s = hbm_total / n_dev / A.HBM_BW
    coll_s = stats.total_bytes / A.ICI_BW
    terms = {"compute_s": comp_s, "memory_s": mem_s, "collective_s": coll_s}
    bottleneck = max(terms, key=terms.get).replace("_s", "")
    rec.update(
        status="OK", n_devices=n_dev,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        arg_gib=round(ma.argument_size_in_bytes / 2**30, 3),
        temp_gib=round(ma.temp_size_in_bytes / 2**30, 3),
        out_gib=round(ma.output_size_in_bytes / 2**30, 3),
        fits_hbm=bool((ma.argument_size_in_bytes + ma.temp_size_in_bytes
                       + ma.output_size_in_bytes) < 16 * 2**30),
        param_bytes_total=param_bytes, opt_bytes_total=opt_bytes,
        cache_bytes_total=cache_bytes,
        flops_per_dev=flops_total / n_dev,
        hbm_bytes_per_dev=hbm_total / n_dev,
        collective_bytes_per_dev=stats.total_bytes,
        collective_counts=stats.counts,
        xla_entry_flops=float(ca.get("flops", 0.0)),
        compute_s=comp_s, memory_s=mem_s, collective_s=coll_s,
        bottleneck=bottleneck,
        model_flops=mf, useful_ratio=(mf / flops_total if flops_total else 0),
    )
    if verbose:
        print(f"  flops/dev={flops_total / n_dev:.3e}  "
              f"hbm/dev={hbm_total / n_dev:.3e}B  "
              f"coll/dev={stats.total_bytes:.3e}B")
        print(f"  roofline: compute={comp_s * 1e3:.2f}ms  "
              f"memory={mem_s * 1e3:.2f}ms  collective={coll_s * 1e3:.2f}ms"
              f"  -> {bottleneck}-bound; "
              f"useful={rec['useful_ratio'] * 100:.0f}%")
        print(f"  collectives: {stats.counts}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None,
                    choices=list(SH.SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape), single+multi pod")
    ap.add_argument("--scheme", default=None, choices=["1d", "2d", "none"])
    ap.add_argument("--impl", default=None,
                    choices=["ring", "ring_chunked", "ring_fused", "rs",
                             "gspmd", "allreduce"])
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--q-chunk", type=int, default=None,
                    help="chunked attention query-block size (beyond-paper)")
    ap.add_argument("--kv-shard", default=None,
                    choices=["auto", "heads", "seq", "headdim"])
    ap.add_argument("--zero1", action="store_true",
                    help="ZeRO-1: shard optimizer moments over data too")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args()

    combos = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SH.SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = [False, True] if (args.all or args.both_meshes) \
        else [args.multi_pod]
    for a in archs:
        for sh in shapes:
            for mp in meshes:
                combos.append((a, sh, mp))

    results = []
    for a, sh, mp in combos:
        rec = run_one(a, sh, multi_pod=mp, scheme=args.scheme,
                      impl=args.impl, q_chunk=args.q_chunk,
                      kv_shard=args.kv_shard, zero1=args.zero1,
                      remat=False if args.no_remat else None)
        results.append(rec)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")

    n_ok = sum(r["status"] == "OK" for r in results)
    n_skip = sum(r["status"] == "SKIP" for r in results)
    n_fail = sum(r["status"] == "FAIL" for r in results)
    print(f"\n== dry-run summary: {n_ok} OK, {n_skip} SKIP (documented), "
          f"{n_fail} FAIL ==")
    if n_fail:
        for r in results:
            if r["status"] == "FAIL":
                print(f"  FAIL {r['arch']} x {r['shape']} "
                      f"mp={r['multi_pod']}: {r['error']}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
