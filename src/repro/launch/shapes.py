"""Assigned input shapes and ShapeDtypeStruct builders for the dry-run.

INPUT SHAPES (assigned):
  train_4k       seq_len=  4,096  global_batch=256   (training)
  prefill_32k    seq_len= 32,768  global_batch= 32   (inference-prefill)
  decode_32k     seq_len= 32,768  global_batch=128   (inference-decode)
  long_500k      seq_len=524,288  global_batch=  1   (long-context decode)

No device memory is ever allocated here: parameters come from
``jax.eval_shape`` over the real init, inputs are ShapeDtypeStructs.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import precision
from repro.core.api import JigsawConfig
from repro.core.sharding import RULES_1D, RULES_2D, ShardingRules
from repro.launch import specs as S
from repro.models import registry as M
from repro.optim import adam


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Is (arch x shape) runnable? Returns (ok, reason-if-not)."""
    if cfg.family == "mixer" and shape.kind == "decode":
        return False, "forecast model: no autoregressive decode step"
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: long_500k needs "
                       "sub-quadratic attention (DESIGN.md skip)")
    return True, ""


def mixer_grid_for(shape: ShapeSpec, cfg: ModelConfig) -> Tuple[int, int]:
    """WeatherMixer interprets seq_len as its token count: pick a
    (lat, lon) grid with ~seq_len patches.  prefill_32k lands on
    1456x1440 ~= the paper's 0.25-degree global grid."""
    p = cfg.wm_patch
    if shape.name == "train_4k":
        return 512, 512          # 4096 tokens at patch 8
    if shape.name == "prefill_32k":
        return 1456, 1440        # 32760 tokens: paper-scale resolution
    t = shape.seq_len
    side = int(np.sqrt(t)) * p
    return side, side


def rules_for(cfg: ModelConfig) -> ShardingRules:
    return RULES_2D if cfg.scheme == "2d" else RULES_1D


def jigsaw_for(cfg: ModelConfig) -> JigsawConfig:
    pol = precision.policy_of(cfg)
    # legacy (no named policy): keep compute_dtype unset so the hot path
    # is byte-for-byte what it was before the precision subsystem
    cd = None if pol.name == "legacy" else pol.compute_dtype
    return JigsawConfig(rules=rules_for(cfg), scheme=cfg.scheme,
                        impl=cfg.impl, fsdp=cfg.shard_params_over_data,
                        kernel=cfg.kernel, accum_dtype=pol.accum_dtype,
                        compute_dtype=cd)


def _sds(shape, dtype, mesh: Mesh, spec: P):
    spec = S.sanitize_spec(shape, spec, mesh)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def param_structs(cfg: ModelConfig, mesh: Mesh, rules: ShardingRules):
    """Parameter ShapeDtypeStructs with Jigsaw shardings (no allocation)."""
    shapes = jax.eval_shape(partial(M.init, cfg=cfg), jax.random.key(0))
    pspecs = S.param_specs(shapes, cfg, rules, mesh)
    pspecs = S.sanitize_tree(shapes, pspecs, mesh)
    structs = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        shapes, pspecs)
    return structs, pspecs


def opt_structs(params_structs, pspecs, cfg: ModelConfig, mesh: Mesh,
                adam_cfg: adam.AdamConfig, zero1: bool = False):
    shapes = jax.eval_shape(partial(adam.init, cfg=adam_cfg),
                            params_structs)
    ospecs = S.opt_specs(shapes["mu"], pspecs,
                         zero1_axis="data" if zero1 else None, mesh=mesh,
                         master="master" in shapes)
    ospecs = S.sanitize_tree(shapes, ospecs, mesh)
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        shapes, ospecs), ospecs


def input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                rules: ShardingRules):
    """ShapeDtypeStructs for the step function's data arguments."""
    bs = S.batch_specs(cfg, rules)
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "mixer":
        lat, lon = mixer_grid_for(shape, cfg)
        fshape = (b, lat, lon, cfg.wm_channels)
        return {"fields": _sds(fshape, jnp.float32, mesh, bs["fields"]),
                "target": _sds(fshape, jnp.float32, mesh, bs["target"])}

    if shape.kind == "decode":
        # decode consumes [B, 1] tokens; the cache carries seq_len.
        return {"tokens": _sds((b, 1), jnp.int32, mesh, bs["tokens"])}

    batch = {}
    s_text = s
    if cfg.family == "vlm":
        npatch = cfg.n_patches
        s_text = s - npatch
        batch["embeds"] = _sds((b, npatch, cfg.d_model),
                               jnp.dtype(cfg.compute_dtype), mesh,
                               bs["embeds"])
    if cfg.family == "audio":
        batch["frames"] = _sds((b, cfg.n_frames, cfg.d_model),
                               jnp.dtype(cfg.compute_dtype), mesh,
                               bs["frames"])
    batch["tokens"] = _sds((b, s_text), jnp.int32, mesh, bs["tokens"])
    if shape.kind == "train":
        batch["labels"] = _sds((b, s_text), jnp.int32, mesh, bs["labels"])
    return batch


def cache_structs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                  rules: ShardingRules):
    shapes = jax.eval_shape(
        partial(M.init_cache, cfg, shape.global_batch, shape.seq_len))
    cspecs = S.cache_specs(shapes, cfg, rules, mesh)
    cspecs = S.sanitize_tree(shapes, cspecs, mesh)
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        shapes, cspecs), cspecs
