"""End-to-end training driver.

CPU-runnable (reduced configs, host mesh) and production-shaped (full
configs on the 16x16 mesh) from the same entry point:

  PYTHONPATH=src python -m repro.launch.train --arch weathermixer-1b \
      --reduced --steps 200 --batch 8 [--mesh-model 4 --mesh-data 2] \
      [--scheme 2d] [--rollout 3] [--ckpt out/ckpt]

Reduced configs run real optimization on the synthetic pipelines; the
loss curves in EXPERIMENTS.md come from here.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.core.api import JigsawConfig
from repro.core.sharding import RULES_1D, RULES_2D
from repro.data.tokens import TokenDataConfig, TokenDataset
from repro.data.weather import WeatherDataConfig, WeatherDataset
from repro.launch import shapes as SH
from repro.launch import specs as S
from repro.launch.mesh import make_host_mesh
from repro.models import registry as M
from repro.optim import adam, schedule as sched
from repro.checkpoint import io as ckpt_io
from repro.train.step import make_train_step


def make_batch_fn(cfg, seq_len: int, seed: int = 0):
    """Returns batch_fn(step, batch_size, horizon=1) -> host numpy batch."""
    if cfg.family == "mixer":
        ds = WeatherDataset(WeatherDataConfig(
            lat=cfg.wm_lat, lon=cfg.wm_lon, channels=cfg.wm_channels,
            seed=seed))
        return lambda step, bsz, horizon=1: ds.sample_batch(
            step, bsz, horizon=horizon)
    tok = TokenDataset(TokenDataConfig(vocab_size=cfg.vocab_size,
                                       seq_len=seq_len, seed=seed))

    def fn(step, bsz, horizon=1):
        del horizon
        batch = tok.sample_batch(step, bsz)
        if cfg.family == "vlm":
            rng = np.random.default_rng(step)
            batch["embeds"] = rng.normal(
                0, 1, (bsz, cfg.n_patches, cfg.d_model)).astype(np.float32)
        if cfg.family == "audio":
            rng = np.random.default_rng(step)
            batch["frames"] = rng.normal(
                0, 1, (bsz, cfg.n_frames, cfg.d_model)).astype(np.float32)
        return batch

    return fn


def train(arch: str, *, steps: int = 100, batch: int = 8, seq_len: int = 128,
          reduced: bool = True, mesh_model: int = 1, mesh_data: int = 1,
          scheme: str = None, impl: str = None, rollout: int = 1,
          lr: float = 1e-3, log_every: int = 10, ckpt: str = None,
          seed: int = 0, metrics_out: str = None, init_params=None):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    if scheme:
        cfg = cfg.replace(scheme=scheme)
    if impl:
        cfg = cfg.replace(impl=impl)

    use_mesh = mesh_model * mesh_data > 1
    if use_mesh:
        mesh = make_host_mesh(model=mesh_model, data=mesh_data,
                              two_d=cfg.scheme == "2d")
        rules = SH.rules_for(cfg)
    else:
        mesh = None
        cfg = cfg.replace(scheme="none")
        rules = RULES_1D
    jcfg = SH.jigsaw_for(cfg).replace(rules=rules)

    key = jax.random.PRNGKey(seed)
    # copy init_params: the step donates its buffers, and the caller may
    # still hold them (e.g. fig56 evaluates the base model afterwards)
    params = M.init(key, cfg) if init_params is None \
        else jax.tree.map(jnp.copy, init_params)
    acfg = adam.AdamConfig(weight_decay=0.0)
    opt_state = adam.init(params, acfg)
    lr_fn = partial(sched.warmup_cosine, base_lr=lr,
                    warmup_steps=max(steps // 10, 1), total_steps=steps,
                    min_lr=lr * 0.1)
    # randomized-rollout fine-tuning (paper §6): each update draws a
    # rollout length r in [1, rollout]; the processor runs r times and
    # the target is the state r steps ahead.  One jitted step per r.
    step_fns = {r: jax.jit(make_train_step(cfg, jcfg, adam_cfg=acfg,
                                           lr_fn=lr_fn, rollout=r),
                           donate_argnums=(0, 1))
                for r in range(1, rollout + 1)}
    batch_fn = make_batch_fn(cfg, seq_len, seed)
    r_rng = np.random.default_rng(seed + 1)
    r_sched = (r_rng.integers(1, rollout + 1, steps) if rollout > 1
               else np.ones(steps, np.int64))

    def run():
        nonlocal params, opt_state
        history = []
        t0 = time.time()
        for i in range(steps):
            r = int(r_sched[i])
            hb = batch_fn(i, batch, horizon=r)
            b = {k: jnp.asarray(v) for k, v in hb.items()}
            if use_mesh:
                bspecs = S.batch_specs(cfg, rules)
                b = {k: jax.device_put(
                        v, jax.NamedSharding(mesh, S.sanitize_spec(
                            v.shape, bspecs.get(k, jax.P()), mesh)))
                     for k, v in b.items()}
            params, opt_state, metrics = step_fns[r](params, opt_state, b)
            if i % log_every == 0 or i == steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = i
                m["wall_s"] = round(time.time() - t0, 1)
                history.append(m)
                print(f"step {i:5d}  loss {m['loss']:.4f}  "
                      f"lr {m['lr']:.2e}  ({m['wall_s']}s)")
        return history

    if use_mesh:
        with jax.set_mesh(mesh):
            history = run()
    else:
        history = run()

    if ckpt:
        ckpt_io.save(ckpt, params, opt_state, steps,
                     extra={"arch": arch, "reduced": reduced})
        print(f"checkpoint -> {ckpt}")
    if metrics_out:
        with open(metrics_out, "w") as f:
            json.dump(history, f, indent=1)
    return history, params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="full (non-reduced) config -- needs real hardware")
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--scheme", default=None, choices=["1d", "2d", "none"])
    ap.add_argument("--impl", default=None,
                    choices=["ring", "rs", "gspmd", "allreduce"])
    ap.add_argument("--rollout", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--metrics-out", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    train(args.arch, steps=args.steps, batch=args.batch,
          seq_len=args.seq_len, reduced=not args.full,
          mesh_model=args.mesh_model, mesh_data=args.mesh_data,
          scheme=args.scheme, impl=args.impl, rollout=args.rollout,
          lr=args.lr, ckpt=args.ckpt, seed=args.seed,
          metrics_out=args.metrics_out)


if __name__ == "__main__":
    main()
