"""End-to-end training driver: a thin CLI over ``TrainEngine``.

CPU-runnable (reduced configs, host mesh) and production-shaped (full
configs on the 16x16 mesh) from the same entry point:

  PYTHONPATH=src python -m repro.launch.train --arch weathermixer-1b \
      --reduced --steps 200 --batch 8 [--mesh-model 4 --mesh-data 2] \
      [--scheme 2d] [--rollout 3] [--ckpt out/ckpt] [--ckpt-every 50] \
      [--resume out/ckpt-50] [--pipeline sharded|sync-full] \
      [--prefetch 2] [--accum 2]

Checkpoints are zero-redundancy sharded (each rank writes only its
addressable shards, streamed by a background writer; DESIGN.md §9);
``--resume`` continues an interrupted run with a bit-identical loss
history.

The input path is the domain-parallel sharded pipeline by default: each
model-parallel rank generates only its (lon x channel) partition and a
background thread prefetches ahead of compute (paper §5).
``--pipeline sync-full`` restores the legacy full-batch host generation
for A/B comparison; both produce bit-identical batches.

Reduced configs run real optimization on the synthetic pipelines; the
loss curves in EXPERIMENTS.md come from here.

Fault tolerance (DESIGN.md §12): the CLI installs a
``PreemptionHandler`` -- SIGTERM/SIGUSR1 finishes the in-flight step,
takes a final synchronous save, and exits code 75 (resumable).
``--supervise --max-restarts N`` wraps the whole thing in the
``Supervisor`` relaunch loop, which rediscovers the latest COMPLETE
checkpoint before every launch and passes it as ``--resume``.
"""
from __future__ import annotations

import argparse
import sys

from repro.configs.registry import ARCH_IDS
from repro.launch import resilience
from repro.launch.engine import EngineConfig, TrainEngine


def train(arch: str, *, steps: int = 100, batch: int = 8, seq_len: int = 128,
          reduced: bool = True, mesh_model: int = 1, mesh_data: int = 1,
          scheme: str = None, impl: str = None, kernel: str = None,
          precision: str = None, rollout: int = 1,
          lr: float = 1e-3, log_every: int = 10, ckpt: str = None,
          ckpt_every: int = 0, keep_ckpts: int = 0, resume: str = None,
          async_save: bool = True,
          seed: int = 0, metrics_out: str = None,
          metrics_format: str = "jsonl", trace: str = None,
          telemetry: bool = True, init_params=None,
          pipeline: str = "sharded", prefetch: int = 2, accum: int = 1,
          zero1: bool = False, eval_every: int = 0, config_override=None,
          preemption: bool = False, preempt_at_step: int = None):
    """Back-compat functional entry point; returns (history, params).

    New callers should construct a :class:`TrainEngine` directly --
    it exposes the same behavior plus eval/checkpoint/benchmark hooks.
    ``config_override`` replaces the registry config (used by benchmarks
    and examples that sweep custom model sizes)."""
    engine = TrainEngine(
        arch, reduced=reduced, mesh_model=mesh_model, mesh_data=mesh_data,
        scheme=scheme, impl=impl, kernel=kernel, init_params=init_params,
        config_override=config_override,
        config=EngineConfig(
            steps=steps, batch=batch, seq_len=seq_len, rollout=rollout,
            lr=lr, log_every=log_every, ckpt=ckpt, ckpt_every=ckpt_every,
            keep_ckpts=keep_ckpts, resume=resume, async_save=async_save,
            seed=seed, precision=precision,
            metrics_out=metrics_out, metrics_format=metrics_format,
            trace=trace, telemetry=telemetry,
            pipeline=pipeline, prefetch=prefetch,
            accum=accum, zero1=zero1, eval_every=eval_every,
            preemption=preemption, preempt_at_step=preempt_at_step))
    history = engine.run()
    return history, engine.params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="full (non-reduced) config -- needs real hardware")
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--scheme", default=None, choices=["1d", "2d", "none"])
    ap.add_argument("--impl", default=None,
                    choices=["ring", "ring_chunked", "ring_fused", "rs",
                             "gspmd", "allreduce"])
    ap.add_argument("--kernel", default=None, choices=["xla", "pallas"],
                    help="local GEMM engine (pallas = MXU-tiled fused "
                         "kernels; interpret mode on CPU)")
    ap.add_argument("--precision", default=None,
                    choices=["fp32", "bf16", "bf16_pure"],
                    help="precision policy (core/precision): bf16 = bf16 "
                         "compute/comm + fp32 master weights; bf16_pure = "
                         "bf16 everywhere (memory-minimal)")
    ap.add_argument("--rollout", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint dir (sharded manifest format); "
                         "periodic saves land at <ckpt>-<step>")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="save every N steps (0 = final only)")
    ap.add_argument("--keep-ckpts", type=int, default=0,
                    help="keep only the last K periodic checkpoints "
                         "(0 = keep all; the best-eval marker's target "
                         "is never deleted)")
    ap.add_argument("--resume", default=None,
                    help="checkpoint dir to exact-resume from (restores "
                         "params/opt/step/rollout schedule/data cursor)")
    ap.add_argument("--sync-save", action="store_true",
                    help="block the loop on checkpoint writes instead of "
                         "the async background writer")
    ap.add_argument("--metrics-out", default=None)
    ap.add_argument("--metrics-format", default="jsonl",
                    choices=["jsonl", "json"],
                    help="jsonl (default): crash-safe append, one JSON "
                         "object per line; json: legacy whole-history "
                         "dump written once at run end")
    ap.add_argument("--trace", default=None,
                    help="Chrome trace-event export path (load in "
                         "Perfetto); a sibling .jsonl gets the per-step "
                         "mfu/comm_fraction records for "
                         "launch/trace_report.py")
    ap.add_argument("--no-telemetry", action="store_true",
                    help="disable span tracing (the overhead benchmark's "
                         "baseline; counters stay live)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pipeline", default="sharded",
                    choices=["sharded", "sync-full"],
                    help="domain-parallel sharded reads (default) or the "
                         "legacy full-batch host generation")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="input batches prefetched by the background "
                         "thread (0 = synchronous)")
    ap.add_argument("--accum", type=int, default=1,
                    help="microbatch gradient-accumulation factor")
    ap.add_argument("--zero1", action="store_true",
                    help="ZeRO-1: shard optimizer moments over data")
    ap.add_argument("--eval-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--supervise", action="store_true",
                    help="run under the relaunch Supervisor: restart on "
                         "resumable exits / crashes, auto-resuming from "
                         "the latest complete checkpoint (needs --ckpt)")
    ap.add_argument("--max-restarts", type=int, default=3,
                    help="relaunch budget under --supervise")
    args = ap.parse_args()
    if args.supervise:
        if not args.ckpt:
            ap.error("--supervise requires --ckpt (the supervisor "
                     "discovers resume points under its directory)")
        sys.exit(resilience.supervise_train_cli(args, sys.argv[1:]))
    try:
        train(args.arch, steps=args.steps, batch=args.batch,
              seq_len=args.seq_len, reduced=not args.full,
              mesh_model=args.mesh_model, mesh_data=args.mesh_data,
              scheme=args.scheme, impl=args.impl, kernel=args.kernel,
              precision=args.precision, rollout=args.rollout,
              lr=args.lr, log_every=args.log_every,
              ckpt=args.ckpt, ckpt_every=args.ckpt_every,
              keep_ckpts=args.keep_ckpts,
              resume=args.resume, async_save=not args.sync_save,
              seed=args.seed,
              metrics_out=args.metrics_out,
              metrics_format=args.metrics_format, trace=args.trace,
              telemetry=not args.no_telemetry, pipeline=args.pipeline,
              prefetch=args.prefetch, accum=args.accum, zero1=args.zero1,
              eval_every=args.eval_every, preemption=True)
    except resilience.Preempted as p:
        print(f"[train] {p}; exiting resumable "
              f"({resilience.RESUMABLE_EXIT_CODE})")
        sys.exit(resilience.RESUMABLE_EXIT_CODE)


if __name__ == "__main__":
    main()
