"""Fault-tolerant elastic training: preemption handling + supervised
relaunch (ROADMAP item 3, DESIGN.md §12).

The 256-GPU regime the paper trains in is exactly where node loss and
preemption are routine; a long campaign survives them with three layers:

* :class:`PreemptionHandler` -- catches SIGTERM/SIGUSR1 (the signals
  cluster schedulers send before reclaiming a node), lets the in-flight
  step finish, and tells the engine to take a final SYNCHRONOUS save
  and raise :class:`Preempted`.  ``launch/train.py`` translates that
  into :data:`RESUMABLE_EXIT_CODE` so a supervisor can distinguish
  "preempted, checkpoint durable, relaunch me" from a crash.

* :class:`Supervisor` -- the relaunch loop behind ``--supervise
  --max-restarts N``: runs the training command, auto-discovers the
  latest COMPLETE checkpoint (``repro.checkpoint.latest_checkpoint``
  validates manifest + shard files, so torn saves are never resumed
  from) before every launch, restarts immediately on a resumable exit
  and with jittered exponential backoff on a crash.

* elastic resharding lives in ``TrainEngine._restore``: the checkpoint
  may have been written on a DIFFERENT mesh shape -- the engine refits
  params and ZeRO-1 moment/master layouts to the current mesh, so an
  8-way job that lost a node continues on the survivors.

Deterministic chaos-testing hook: ``REPRO_PREEMPT_AT_STEP=N`` (or
``EngineConfig(preempt_at_step=N)``) makes the handler deliver a REAL
``SIGTERM`` to its own process after training step ``N`` completes --
the full signal path is exercised, at a reproducible step.
"""
from __future__ import annotations

import os
import random
import signal
import subprocess
import sys
import time
import warnings
from typing import Callable, List, Optional, Sequence, Tuple

from repro import telemetry
from repro.checkpoint.sharded import (checkpoint_complete,  # noqa: F401
                                      latest_checkpoint)

# EX_TEMPFAIL: the sysexits.h "transporter can retry" code -- distinct
# from 0 (done) and from crash codes, so a supervisor knows the exit was
# an orderly preemption with a durable checkpoint behind it.
RESUMABLE_EXIT_CODE = 75

PREEMPT_ENV = "REPRO_PREEMPT_AT_STEP"


class Preempted(Exception):
    """Raised out of ``TrainEngine.run()`` after a preemption signal:
    the in-flight step finished, the final synchronous save (when a
    checkpoint path is configured) is durable, and the process should
    exit :data:`RESUMABLE_EXIT_CODE`."""

    def __init__(self, step: int, checkpoint: Optional[str] = None,
                 signum: Optional[int] = None):
        self.step = step
        self.checkpoint = checkpoint
        self.signum = signum
        super().__init__(
            f"preempted at step {step} (checkpoint={checkpoint!r}, "
            f"signal={signum})")


def _env_int(name: str) -> Optional[int]:
    val = os.environ.get(name)
    return int(val) if val not in (None, "") else None


class PreemptionHandler:
    """Signal-driven stop flag for the training loop.

    ``install()`` replaces the process handlers for ``signals`` (default
    SIGTERM + SIGUSR1) with a flag-setter; the engine calls ``poll(i)``
    after each completed step and, when it returns True, finishes with a
    final synchronous save instead of dying mid-write.  ``uninstall()``
    restores the previous handlers (the engine does this in a finally).

    Handlers can only be installed from the main thread; elsewhere the
    handler degrades to an inert flag with a warning (the supervisor
    still restarts on the raw kill, it just loses the final save).
    """

    DEFAULT_SIGNALS = (signal.SIGTERM, signal.SIGUSR1)

    def __init__(self, signals: Sequence[int] = DEFAULT_SIGNALS,
                 preempt_at_step: Optional[int] = None):
        self.signals = tuple(signals)
        self.received: Optional[int] = None   # signal number once caught
        self.preempt_at_step = (preempt_at_step
                                if preempt_at_step is not None
                                else _env_int(PREEMPT_ENV))
        self._prev: dict = {}
        self.installed = False

    # -- signal plumbing -------------------------------------------------
    def _on_signal(self, signum, frame):
        del frame
        self.received = signum

    def install(self) -> "PreemptionHandler":
        try:
            for s in self.signals:
                self._prev[s] = signal.signal(s, self._on_signal)
            self.installed = True
        except ValueError:
            # not the main thread: restore whatever we managed to set
            self.uninstall()
            warnings.warn(
                "PreemptionHandler: signal handlers can only be installed "
                "from the main thread; signal-driven final saves disabled "
                "for this run")
        return self

    def uninstall(self) -> None:
        for s, prev in self._prev.items():
            signal.signal(s, prev)
        self._prev = {}
        self.installed = False

    def __enter__(self) -> "PreemptionHandler":
        return self.install()

    def __exit__(self, *exc) -> None:
        self.uninstall()

    # -- loop interface --------------------------------------------------
    @property
    def should_stop(self) -> bool:
        return self.received is not None

    def poll(self, step: int) -> bool:
        """True once a preemption signal has arrived.  With the chaos
        hook armed (``preempt_at_step``), completing that step delivers
        a real SIGTERM to this process first -- the production signal
        path, at a deterministic step."""
        if (self.installed and not self.should_stop
                and self.preempt_at_step is not None
                and step == self.preempt_at_step):
            # emitted here, NOT in _on_signal: the tracer lock is not
            # async-signal-safe
            telemetry.get_tracer().event("preempt.chaos_sigterm",
                                         step=step)
            os.kill(os.getpid(), signal.SIGTERM)
        return self.should_stop


class Supervisor:
    """Relaunch loop: run a training command until it exits clean, with
    automatic resume-from-latest-complete-checkpoint on every launch.

    Parameters
    ----------
    build_cmd : (resume_path, attempt) -> argv list.  ``resume_path`` is
        the newest COMPLETE checkpoint under ``ckpt_root`` (None on a
        cold start), rediscovered before EVERY launch so a relaunch
        always continues from the most recent durable save -- including
        one written by a previous supervisor incarnation.
    ckpt_root : directory scanned by ``latest_checkpoint``; ``prefix``
        restricts discovery to ``<prefix>`` / ``<prefix>-*`` entries
        (the engine's ``--ckpt out/ck`` layout -> root="out", prefix="ck").
    max_restarts : relaunch budget.  Resumable exits restart immediately
        (the work is checkpointed; waiting buys nothing); crash exits
        back off exponentially with jitter up to ``max_backoff``.
    run_cmd / sleep_fn : injectable for tests.
    """

    def __init__(self, build_cmd: Callable[[Optional[str], int], List[str]],
                 *, ckpt_root: Optional[str] = None,
                 prefix: Optional[str] = None, max_restarts: int = 3,
                 backoff: float = 1.0, max_backoff: float = 60.0,
                 resumable_codes: Tuple[int, ...] = (RESUMABLE_EXIT_CODE,),
                 env: Optional[dict] = None,
                 run_cmd: Optional[Callable[[List[str]], int]] = None,
                 sleep_fn: Callable[[float], None] = time.sleep):
        self.build_cmd = build_cmd
        self.ckpt_root = ckpt_root
        self.prefix = prefix
        self.max_restarts = max_restarts
        self.backoff = backoff
        self.max_backoff = max_backoff
        self.resumable_codes = tuple(resumable_codes)
        self.env = env
        self._run_cmd = run_cmd or (
            lambda argv: subprocess.call(argv, env=self.env))
        self.sleep_fn = sleep_fn
        self.attempts: List[int] = []      # exit code per launch
        self.resumes: List[Optional[str]] = []  # resume path per launch
        self.backoffs: List[float] = []    # sleeps taken (crash restarts)

    def _discover(self) -> Optional[str]:
        if not self.ckpt_root:
            return None
        return latest_checkpoint(self.ckpt_root, prefix=self.prefix)

    def run(self) -> int:
        tr = telemetry.get_tracer()
        restarts = 0
        delay = self.backoff
        while True:
            resume = self._discover()
            argv = self.build_cmd(resume, len(self.attempts))
            self.resumes.append(resume)
            tr.event("supervisor.launch", attempt=len(self.attempts),
                     resume=resume)
            with tr.span("supervisor.attempt",
                         attempt=len(self.attempts)):
                rc = self._run_cmd(argv)
            self.attempts.append(rc)
            tr.event("supervisor.exit", attempt=len(self.attempts) - 1,
                     code=rc)
            if rc == 0:
                return 0
            if restarts >= self.max_restarts:
                print(f"[supervisor] exit {rc} with no restart budget "
                      f"left ({self.max_restarts}); giving up")
                tr.event("supervisor.give_up", code=rc,
                         restarts=restarts)
                return rc
            restarts += 1
            tr.counter("supervisor.restarts")
            if rc in self.resumable_codes:
                print(f"[supervisor] resumable exit ({rc}); relaunching "
                      f"immediately (restart {restarts}/{self.max_restarts})")
                tr.counter("supervisor.resumable_restarts")
                continue
            sleep = delay * (1.0 + 0.25 * random.random())
            print(f"[supervisor] crash exit ({rc}); backing off "
                  f"{sleep:.1f}s then relaunching "
                  f"(restart {restarts}/{self.max_restarts})")
            tr.event("supervisor.backoff", seconds=sleep, code=rc)
            self.backoffs.append(sleep)
            self.sleep_fn(sleep)
            delay = min(delay * 2.0, self.max_backoff)


def strip_args(argv: Sequence[str], flags: Sequence[str],
               valued: Sequence[str] = ()) -> List[str]:
    """Drop bare ``flags`` and ``valued`` options (both ``--x v`` and
    ``--x=v`` forms) from an argv copy -- used to rebuild the child
    command from the supervisor's own argv."""
    out: List[str] = []
    skip = False
    for a in argv:
        if skip:
            skip = False
            continue
        if a in flags:
            continue
        if a in valued:
            skip = True
            continue
        if any(a.startswith(v + "=") for v in valued):
            continue
        out.append(a)
    return out


def supervise_train_cli(args, argv: Sequence[str]) -> int:
    """The ``--supervise`` mode of ``launch/train.py``: relaunch this
    same command (minus the supervisor flags, plus ``--resume <latest>``)
    until it exits clean or the restart budget runs out."""
    root = os.path.dirname(os.path.abspath(args.ckpt)) or "."
    prefix = os.path.basename(args.ckpt)
    base = strip_args(argv, flags=("--supervise",),
                      valued=("--max-restarts", "--resume"))

    def build(resume: Optional[str], attempt: int) -> List[str]:
        del attempt
        cmd = [sys.executable, "-m", "repro.launch.train"] + list(base)
        if resume:
            cmd += ["--resume", resume]
        return cmd

    sup = Supervisor(build, ckpt_root=root, prefix=prefix,
                     max_restarts=args.max_restarts)
    return sup.run()
