"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS before anything else imports jax.
"""
from __future__ import annotations

from repro.compat import AxisType, make_mesh

AUTO = AxisType.Auto


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod-slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is
    an outer data-parallel axis (the paper's inter-node DP)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AUTO,) * len(axes))


def make_production_mesh_2d(*, multi_pod: bool = False):
    """Mesh variant for 2-D Jigsaw (paper's 4-way generalized to 4x4):
    the 16-way model axis factored into (mdom=4, mtp=4)."""
    shape = (2, 16, 4, 4) if multi_pod else (16, 4, 4)
    axes = (("pod", "data", "mdom", "mtp") if multi_pod
            else ("data", "mdom", "mtp"))
    return make_mesh(shape, axes, axis_types=(AUTO,) * len(axes))


def make_host_mesh(model: int = 4, data: int = 2, *, two_d: bool = False):
    """Small mesh over host-emulated devices (tests, examples)."""
    if two_d:
        import math
        q = int(math.isqrt(model))
        assert q * q == model
        return make_mesh((data, q, q), ("data", "mdom", "mtp"),
                         axis_types=(AUTO,) * 3)
    return make_mesh((data, model), ("data", "model"),
                     axis_types=(AUTO,) * 2)
