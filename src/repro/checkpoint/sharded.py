"""Zero-redundancy sharded checkpoint save/restore.

Save never materializes the full model anywhere: each rank serializes
only the ADDRESSABLE shards it owns (``leaf.addressable_shards``,
replica 0 only, so replicated leaves are written exactly once), into
one npz per writing device plus a ``manifest.json`` describing the
global layout (``repro.checkpoint.manifest``).  A jigsaw + ZeRO-1
sharded run therefore writes ~``total_bytes / n_ranks`` per rank --
the output-side mirror of the paper's §5 domain-parallel input reads.

Restore is topology-free: ``restore_tree(path, like=..., mesh=...,
specs=...)`` reassembles every leaf from whichever shard files overlap
the slices the CURRENT mesh asks for (``jax.make_array_from_callback``)
-- the saving topology (8-way ring, say) does not constrain the
restoring one (4-way).  Shape/dtype are validated against ``like``
leaf-by-leaf; coverage is validated against the manifest.

The save path is split into a synchronous ``snapshot`` (device -> host
copies of the addressable shards; cheap, and required before the train
step donates the buffers) and a ``write_snapshot`` that only touches
host memory + disk -- that split is what lets the async writer
(``repro.checkpoint.writer``) stream files while training continues.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import manifest as MF
from repro.checkpoint.manifest import (Bounds, LeafEntry, Manifest,
                                       ShardEntry, load_manifest)


def _shard_file(device_id: int) -> str:
    return f"shard-d{device_id:05d}.npz"


def _leaf_spec(leaf) -> P:
    sh = getattr(leaf, "sharding", None)
    if isinstance(sh, NamedSharding):
        return sh.spec
    return P()     # single-device / numpy: one full "replicated" shard


def _leaf_shards(leaf):
    """Yield (bounds, device_id, host_array) for the shards THIS process
    must write: addressable + replica 0 (so each index block of the
    global array is written exactly once across all replicas)."""
    if isinstance(leaf, jax.Array) and hasattr(leaf, "addressable_shards"):
        for s in leaf.addressable_shards:
            if s.replica_id != 0:
                continue
            yield (MF.normalize_index(s.index, leaf.shape),
                   s.device.id, np.asarray(s.data))
    else:
        # copy=True: the Snapshot must capture values at submit time,
        # even for host-numpy leaves the caller mutates in place later
        arr = np.array(leaf, copy=True)
        yield (tuple((0, d) for d in arr.shape), 0, arr)


# ---------------------------------------------------------------------------
# Snapshot (synchronous) + write (backgroundable)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Snapshot:
    """Host-side image of a checkpoint: the manifest plus the per-file
    npz payloads.  Holding one of these is enough to finish the save
    with no further access to device memory -- the async writer's unit
    of work."""
    manifest: Manifest
    blobs: Dict[str, Dict[str, np.ndarray]]     # file -> {npz key: data}
    bytes_per_rank: Dict[int, int]              # device id -> bytes written

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_per_rank.values())


def snapshot(groups: Dict[str, Any], *, step: int = 0,
             extra: Optional[dict] = None,
             mesh: Optional[Mesh] = None) -> Snapshot:
    """Copy every addressable (replica-0) shard of every leaf to host.

    ``groups`` maps group name ("params", "opt_state", ...) to a pytree.
    No full-model gather happens: per-rank host memory is bounded by the
    rank's own shard bytes."""
    blobs: Dict[str, Dict[str, np.ndarray]] = {}
    bytes_per_rank: Dict[int, int] = {}
    mgroups: Dict[str, Dict[str, LeafEntry]] = {}
    for group, tree in groups.items():
        entries: Dict[str, LeafEntry] = {}
        for key, leaf in MF.flatten_tree(tree).items():
            if mesh is None:
                sh = getattr(leaf, "sharding", None)
                if isinstance(sh, NamedSharding):
                    mesh = sh.mesh
            shards: List[ShardEntry] = []
            for i, (bounds, dev, data) in enumerate(_leaf_shards(leaf)):
                fname = _shard_file(dev)
                nkey = f"{group}{MF.SEP}{key}#{i}"
                blobs.setdefault(fname, {})[nkey] = data
                bytes_per_rank[dev] = (bytes_per_rank.get(dev, 0)
                                       + data.nbytes)
                shards.append(ShardEntry(fname, nkey, bounds, dev))
            entries[key] = LeafEntry(
                shape=tuple(np.shape(leaf)),
                dtype=np.dtype(getattr(leaf, "dtype",
                                       np.asarray(leaf).dtype)).name,
                spec=MF.spec_to_json(_leaf_spec(leaf)),
                shards=tuple(shards))
        mgroups[group] = entries
    man = Manifest(
        step=int(step), extra=dict(extra or {}),
        mesh_axes=None if mesh is None else tuple(mesh.axis_names),
        mesh_shape=None if mesh is None else tuple(
            mesh.devices.shape if hasattr(mesh, "devices")
            else mesh.shape.values()),
        groups=mgroups)
    return Snapshot(man, blobs, bytes_per_rank)


def _write_npz_atomic(fname: str, members: Dict[str, np.ndarray]) -> None:
    """Write an npz via tmp + os.replace: a process killed mid-write can
    leave a stale ``.tmp`` behind, but never a truncated shard at the
    final name -- so 'file exists' means 'file is whole'."""
    tmp = fname + ".tmp"
    # an open file object sidesteps np.savez's extension munging AND
    # makes the write target explicit
    with open(tmp, "wb") as f:
        # uncompressed: the async writer's job is to get off the train
        # loop's critical path, not to spend CPU on gzip
        np.savez(f, **members)
    os.replace(tmp, fname)


def write_snapshot(snap: Snapshot, path: str, *, process_index: int = 0,
                   process_count: int = 1) -> None:
    """Stream a Snapshot to disk: shard files first (each atomically),
    manifest last (its presence marks the checkpoint complete).

    Pod-scale (``process_count > 1``): every process writes its shard
    files then publishes an ``index-pNNNNN.json`` fragment; process 0
    additionally waits for ALL fragments and merges them into the final
    ``manifest.json`` -- the save is atomic as a whole, not per process
    (a pod save missing any rank's index never grows a manifest, so
    ``latest_checkpoint`` never resumes from it)."""
    os.makedirs(path, exist_ok=True)
    for fname, members in snap.blobs.items():
        _write_npz_atomic(os.path.join(path, fname), members)
    if process_count <= 1:
        snap.manifest.save(path)
        return
    snap.manifest.save_index(path, process_index, process_count)
    if process_index == 0:
        finalize_checkpoint(path, process_count)


def finalize_checkpoint(path: str, process_count: int, *,
                        timeout: float = 120.0,
                        poll: float = 0.05) -> Manifest:
    """Rank 0's merge barrier: wait for every per-process index file,
    merge the fragments, write the global manifest (atomically).  Raises
    ``TimeoutError`` naming the missing ranks if the pod save never
    completes -- the manifest is then never written and the directory
    stays invisible to ``latest_checkpoint``."""
    names = [MF.index_name(i) for i in range(process_count)]
    deadline = time.monotonic() + timeout
    while True:
        missing = [n for n in names
                   if not os.path.exists(os.path.join(path, n))]
        if not missing:
            break
        if time.monotonic() > deadline:
            raise TimeoutError(
                f"checkpoint {path!r}: per-process index files "
                f"{missing} still missing after {timeout}s -- pod save "
                f"incomplete, manifest NOT written")
        time.sleep(poll)
    man = MF.merge_manifests(
        [MF.load_index(path, i) for i in range(process_count)])
    man.save(path)
    return man


def partition_snapshot(snap: Snapshot, assign: Dict[int, int]
                       ) -> Dict[int, Snapshot]:
    """Split a single-process Snapshot into per-process fragments by
    writing device (``assign``: device id -> process index) -- the
    fragment shapes a real multi-host save produces natively, used by
    the emulated pod-save tests.  Every fragment describes the WHOLE
    leaf set (global shapes/specs) with only its own shard entries."""
    out: Dict[int, Snapshot] = {}
    for pi in sorted(set(assign.values())):
        groups: Dict[str, Dict[str, LeafEntry]] = {}
        for g, leaves in snap.manifest.groups.items():
            groups[g] = {
                k: LeafEntry(e.shape, e.dtype, e.spec,
                             tuple(s for s in e.shards
                                   if assign[s.device] == pi))
                for k, e in leaves.items()}
        man = Manifest(step=snap.manifest.step,
                       extra=dict(snap.manifest.extra),
                       mesh_axes=snap.manifest.mesh_axes,
                       mesh_shape=snap.manifest.mesh_shape, groups=groups)
        files = man.shard_files()
        out[pi] = Snapshot(
            man, {f: snap.blobs[f] for f in files},
            {d: b for d, b in snap.bytes_per_rank.items()
             if assign.get(d) == pi})
    return out


def save_checkpoint(path: str, groups: Dict[str, Any], *, step: int = 0,
                    extra: Optional[dict] = None,
                    mesh: Optional[Mesh] = None) -> Snapshot:
    """Synchronous sharded save; returns the Snapshot (byte accounting)."""
    snap = snapshot(groups, step=step, extra=extra, mesh=mesh)
    write_snapshot(snap, path)
    return snap


# ---------------------------------------------------------------------------
# Restore
# ---------------------------------------------------------------------------

class _ShardReader:
    """Lazy reader over a checkpoint's npz files: ``np.load`` on an
    uncompressed npz only materializes the members actually indexed, so
    restoring a small slice of a big checkpoint reads a small file
    region, not the whole thing."""

    def __init__(self, path: str):
        self.path = path
        self._files: Dict[str, Any] = {}

    def member(self, shard: ShardEntry, dtype: np.dtype) -> np.ndarray:
        f = self._files.get(shard.file)
        if f is None:
            fname = os.path.join(self.path, shard.file)
            if not os.path.exists(fname):
                raise FileNotFoundError(
                    f"checkpoint shard file missing: {fname} (partial "
                    f"save, or a multi-host checkpoint restored from "
                    f"one host's files?)")
            f = np.load(fname)
            self._files[shard.file] = f
        raw = f[shard.key]
        # npz stores extension dtypes (bfloat16, float8_*) as raw void
        # bytes ('|Vn'); reinterpret against the manifest's dtype so the
        # values survive the round-trip
        if raw.dtype == dtype:
            return raw
        if raw.dtype.kind == "V" and raw.dtype.itemsize == dtype.itemsize:
            return raw.view(dtype)
        return raw.astype(dtype, copy=False)

    def read(self, entry: LeafEntry, req: Bounds) -> np.ndarray:
        """The ``req`` slice of a global leaf, assembled from every
        saved shard that overlaps it."""
        dtype = np.dtype(entry.dtype)
        for sh in entry.shards:                      # exact-match fast path
            if sh.bounds == req:
                return self.member(sh, dtype)
        out = np.empty([b - a for a, b in req], dtype)
        # boolean coverage mask: overlapping shards must not be able to
        # mask a hole (summing overlap volumes double-counts)
        filled = np.zeros(out.shape, dtype=bool)
        for sh in entry.shards:
            ov = tuple((max(a0, b0), min(a1, b1)) for (a0, a1), (b0, b1)
                       in zip(sh.bounds, req))
            if any(a >= b for a, b in ov):
                continue
            src = tuple(slice(a - s0, b - s0) for (a, b), (s0, _s1)
                        in zip(ov, sh.bounds))
            dst = tuple(slice(a - r0, b - r0) for (a, b), (r0, _r1)
                        in zip(ov, req))
            out[dst] = self.member(sh, dtype)[src]
            filled[dst] = True
        if not filled.all():
            raise ValueError(
                f"shards cover {int(filled.sum())}/{filled.size} elements "
                f"of slice {req} -- manifest inconsistent with shard files")
        return out


def _fit_spec(shape: Tuple[int, ...], spec: P, mesh: Mesh) -> P:
    """Refit a (possibly foreign-topology) spec onto the current mesh:
    drop axes the mesh does not have and axes whose extent does not
    divide the dim (those dims replicate instead)."""
    dims = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for size, e in zip(shape, dims):
        if e is None:
            out.append(None)
            continue
        axes = e if isinstance(e, tuple) else (e,)
        if any(a not in mesh.shape for a in axes):
            out.append(None)
            continue
        extent = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(e if size % extent == 0 else None)
    return P(*out)


def restore_tree(path: str, group: str, *, like=None,
                 mesh: Optional[Mesh] = None,
                 specs=None, manifest: Optional[Manifest] = None,
                 reader: Optional[_ShardReader] = None):
    """Restore one group's pytree from a sharded checkpoint.

    like  : optional pytree validated leaf-by-leaf (shape AND dtype;
            raises naming the offending key path).
    mesh  : target mesh.  None -> plain numpy arrays; otherwise every
            leaf lands as a jax.Array sharded on THIS mesh (which may
            differ from the saving topology), each device reading only
            the shard-file slices it needs.
    specs : optional flat-or-nested {key: PartitionSpec} overriding the
            saved specs (e.g. a new layout after a scheme change).
    """
    man = manifest or load_manifest(path)
    if group not in man.groups:
        raise KeyError(f"checkpoint has no group {group!r} "
                       f"(has {sorted(man.groups)})")
    entries = man.groups[group]
    if like is not None:
        MF.validate_like(entries, like, group)
    sflat = MF.flatten_tree(specs) if specs is not None else {}
    rd = reader or _ShardReader(path)
    out: Dict[str, Any] = {}
    for key, e in entries.items():
        if mesh is None:
            out[key] = rd.read(e, tuple((0, d) for d in e.shape))
            continue
        spec = sflat.get(key, MF.spec_from_json(e.spec))
        sharding = NamedSharding(mesh, _fit_spec(e.shape, spec, mesh))
        out[key] = jax.make_array_from_callback(
            e.shape, sharding,
            lambda idx, e=e: rd.read(e, MF.normalize_index(idx, e.shape)))
    return MF.unflatten_tree(out)


def restore_checkpoint(path: str, like_groups: Optional[Dict[str, Any]]
                       = None, *, mesh: Optional[Mesh] = None, specs=None
                       ) -> Tuple[Dict[str, Any], int, dict]:
    """Restore every group; returns (groups, step, extra).  ``specs``
    maps group name -> spec tree (same override as restore_tree)."""
    man = load_manifest(path)
    rd = _ShardReader(path)
    like_groups = like_groups or {}
    specs = specs or {}
    groups = {g: restore_tree(path, g, like=like_groups.get(g), mesh=mesh,
                              specs=specs.get(g), manifest=man, reader=rd)
              for g in man.groups}
    return groups, man.step, man.extra


# ---------------------------------------------------------------------------
# Completeness + discovery (the auto-resume contract, DESIGN.md §12)
# ---------------------------------------------------------------------------

def checkpoint_complete(path: str) -> bool:
    """True iff ``path`` holds a FINISHED sharded checkpoint: the
    manifest is present and parsable and every shard file it references
    exists.  A save killed mid-flight fails one of these -- shard files
    land atomically (tmp + replace) and the manifest is written last, so
    there is no window where a torn save looks whole."""
    try:
        man = load_manifest(path)
    except Exception:
        return False
    return all(os.path.exists(os.path.join(path, f))
               for f in man.shard_files())


def latest_checkpoint(root: str, prefix: Optional[str] = None
                      ) -> Optional[str]:
    """The newest COMPLETE checkpoint under ``root`` (or ``root``
    itself, if it is one), by manifest step then manifest mtime; torn
    saves -- missing manifest, orphaned index fragments, missing shard
    files -- are skipped, never selected.  ``prefix`` restricts
    discovery to ``<prefix>`` / ``<prefix>-*`` entries (the engine's
    ``--ckpt out/ck`` layout).  Returns None when nothing complete
    exists (cold start)."""
    if not os.path.isdir(root):
        return None
    cands = []
    for name in sorted(os.listdir(root)):
        p = os.path.join(root, name)
        if not os.path.isdir(p):
            continue
        if prefix is not None and name != prefix \
                and not name.startswith(prefix + "-"):
            continue
        cands.append(p)
    if os.path.exists(os.path.join(root, MF.MANIFEST_NAME)):
        cands.append(root)
    best, best_key = None, None
    for p in cands:
        if not checkpoint_complete(p):
            continue
        man = load_manifest(p)
        key = (man.step,
               os.path.getmtime(os.path.join(p, MF.MANIFEST_NAME)))
        if best_key is None or key > best_key:
            best, best_key = p, key
    return best
