"""Zero-redundancy sharded checkpointing (DESIGN.md §9).

* ``manifest``  -- the save/restore metadata contract (global shapes,
                   dtypes, specs, shard index bounds).
* ``sharded``   -- per-rank addressable-shard save, topology-free
                   resharded restore.
* ``writer``    -- async background writer (snapshot on the caller's
                   thread, stream files off the critical path).
* ``serving``   -- read-only params-group restore onto a serving mesh
                   (any shape), with dtype cast to the serving policy.
* ``io``        -- the legacy (path, params, opt_state, step) facade.
"""
from repro.checkpoint.io import restore, save  # noqa: F401
from repro.checkpoint.serving import restore_serving_params  # noqa: F401
from repro.checkpoint.manifest import (Manifest, load_manifest,  # noqa: F401
                                       merge_manifests)
from repro.checkpoint.sharded import (checkpoint_complete,  # noqa: F401
                                      finalize_checkpoint,
                                      latest_checkpoint,
                                      partition_snapshot,
                                      restore_checkpoint,
                                      restore_tree, save_checkpoint,
                                      snapshot, write_snapshot)
from repro.checkpoint.writer import AsyncCheckpointWriter  # noqa: F401
