"""Checkpoint manifest: the metadata contract between save and restore.

A checkpoint directory holds one ``manifest.json`` plus one shard file
per writing rank (``shard-dNNNNN.npz``).  The manifest records, for
every leaf of every group (``params`` / ``opt_state`` / ...):

  * the GLOBAL shape and dtype,
  * the PartitionSpec it was saved under (``null`` entries for
    replicated dims), and
  * the list of shards -- ``(file, npz key, per-dim [start, stop)
    bounds, writing device id)`` -- that tile the global array exactly
    once.

Because the manifest describes global arrays in terms of index bounds
(not devices), restore is topology-free: any mesh whose sharding asks
for a slice of the global array can be served by reading the shard
files that overlap it (``repro.checkpoint.sharded``).  That is the
save-topology != restore-topology contract of DESIGN.md §9.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

FORMAT = "jigsaw-ckpt-v1"
MANIFEST_NAME = "manifest.json"
INDEX_PREFIX = "index-p"
SEP = "/"


def index_name(process_index: int) -> str:
    """Per-process shard index file: each process of a pod-scale save
    publishes one of these (atomically, after its shard files are on
    disk); process 0 merges them into the final ``manifest.json``."""
    return f"{INDEX_PREFIX}{process_index:05d}.json"

Bounds = Tuple[Tuple[int, int], ...]


# ---------------------------------------------------------------------------
# Pytree <-> flat path maps (dict-of-dict trees, the only kind we use)
# ---------------------------------------------------------------------------

def flatten_tree(tree, prefix: str = "") -> Dict[str, Any]:
    """``{"a": {"b": leaf}} -> {"a/b": leaf}`` (leaves untouched)."""
    out: Dict[str, Any] = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(flatten_tree(v, f"{prefix}{k}{SEP}"))
        return out
    out[prefix.rstrip(SEP)] = tree
    return out


def unflatten_tree(flat: Dict[str, Any]):
    tree: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split(SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


# ---------------------------------------------------------------------------
# PartitionSpec serialization
# ---------------------------------------------------------------------------

def spec_to_json(spec) -> List:
    """PartitionSpec -> JSON list: None | "axis" | ["ax1", "ax2"]."""
    out: List = []
    for e in spec:
        if e is None:
            out.append(None)
        elif isinstance(e, (tuple, list)):
            out.append([str(a) for a in e])
        else:
            out.append(str(e))
    return out


def spec_from_json(entries: Sequence):
    from jax.sharding import PartitionSpec as P
    return P(*[tuple(e) if isinstance(e, list) else e for e in entries])


def normalize_index(idx, shape: Tuple[int, ...]) -> Bounds:
    """Concrete per-dim (start, stop) bounds from a tuple of slices."""
    out = []
    for s, dim in zip(idx, shape):
        start = 0 if s.start is None else int(s.start)
        stop = dim if s.stop is None else int(s.stop)
        out.append((start, stop))
    return tuple(out)


# ---------------------------------------------------------------------------
# Manifest records
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardEntry:
    """One saved shard of one leaf."""
    file: str            # npz file (relative to the checkpoint dir)
    key: str             # member key inside the npz
    bounds: Bounds       # per-dim [start, stop) in the global array
    device: int          # writing device id (byte accounting / debug)

    def to_json(self):
        return {"file": self.file, "key": self.key,
                "bounds": [list(b) for b in self.bounds],
                "device": self.device}

    @staticmethod
    def from_json(d) -> "ShardEntry":
        return ShardEntry(d["file"], d["key"],
                          tuple((int(a), int(b)) for a, b in d["bounds"]),
                          int(d["device"]))


@dataclasses.dataclass(frozen=True)
class LeafEntry:
    """Global description of one pytree leaf."""
    shape: Tuple[int, ...]
    dtype: str
    spec: List                       # spec_to_json form
    shards: Tuple[ShardEntry, ...]

    def to_json(self):
        return {"shape": list(self.shape), "dtype": self.dtype,
                "spec": self.spec,
                "shards": [s.to_json() for s in self.shards]}

    @staticmethod
    def from_json(d) -> "LeafEntry":
        return LeafEntry(tuple(d["shape"]), d["dtype"], d["spec"],
                         tuple(ShardEntry.from_json(s)
                               for s in d["shards"]))


@dataclasses.dataclass
class Manifest:
    step: int = 0
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)
    mesh_axes: Optional[Tuple[str, ...]] = None   # saving topology (info)
    mesh_shape: Optional[Tuple[int, ...]] = None
    groups: Dict[str, Dict[str, LeafEntry]] = dataclasses.field(
        default_factory=dict)

    def to_json(self):
        return {
            "format": FORMAT,
            "step": int(self.step),
            "extra": self.extra,
            "mesh": (None if self.mesh_axes is None else
                     {"axes": list(self.mesh_axes),
                      "shape": list(self.mesh_shape)}),
            "groups": {g: {k: e.to_json() for k, e in leaves.items()}
                       for g, leaves in self.groups.items()},
        }

    @staticmethod
    def from_json(d) -> "Manifest":
        if d.get("format") != FORMAT:
            raise ValueError(
                f"not a {FORMAT} checkpoint (format={d.get('format')!r})")
        mesh = d.get("mesh")
        return Manifest(
            step=int(d["step"]), extra=dict(d.get("extra") or {}),
            mesh_axes=None if mesh is None else tuple(mesh["axes"]),
            mesh_shape=None if mesh is None else tuple(mesh["shape"]),
            groups={g: {k: LeafEntry.from_json(e)
                        for k, e in leaves.items()}
                    for g, leaves in d["groups"].items()})

    def shard_files(self):
        """The set of shard files this manifest references -- what must
        exist on disk for the checkpoint to be complete."""
        return {s.file for leaves in self.groups.values()
                for e in leaves.values() for s in e.shards}

    def save(self, path: str) -> None:
        """Write manifest.json atomically (tmp + rename): shard files are
        written FIRST, the manifest LAST, so a crashed save is never
        mistaken for a complete checkpoint."""
        self._dump_json(self.to_json(), path, MANIFEST_NAME)

    def save_index(self, path: str, process_index: int,
                   process_count: int) -> None:
        """Write this process's shard-index fragment (same schema as the
        manifest, shard lists restricted to what THIS process wrote),
        atomically, as the per-process completeness marker of a
        pod-scale save."""
        d = self.to_json()
        d["process"] = {"index": int(process_index),
                        "count": int(process_count)}
        self._dump_json(d, path, index_name(process_index))

    @staticmethod
    def _dump_json(d: dict, path: str, name: str) -> None:
        tmp = os.path.join(path, name + ".tmp")
        with open(tmp, "w") as f:
            json.dump(d, f, indent=1)
        os.replace(tmp, os.path.join(path, name))


def load_manifest(path: str) -> Manifest:
    fname = os.path.join(path, MANIFEST_NAME)
    if not os.path.exists(fname):
        raise FileNotFoundError(
            f"no {MANIFEST_NAME} under {path!r} -- not a sharded "
            f"checkpoint (or an interrupted save)")
    with open(fname) as f:
        return Manifest.from_json(json.load(f))


def load_index(path: str, process_index: int) -> Manifest:
    fname = os.path.join(path, index_name(process_index))
    with open(fname) as f:
        d = json.load(f)
    d.pop("process", None)
    return Manifest.from_json(d)


def merge_manifests(parts: Sequence[Manifest]) -> Manifest:
    """Merge per-process manifest fragments into the global manifest.

    Every fragment carries the SAME leaf set with the same global
    shape/dtype/spec (each process describes the whole pytree, shard
    lists restricted to what it wrote); the merge concatenates the shard
    lists, deduplicating identical ``(file, key)`` entries.  Coverage of
    the merged shard set is validated at restore time by the reader's
    boolean fill mask, so a fragment that silently lost shards still
    fails loudly."""
    if not parts:
        raise ValueError("merge_manifests: no fragments")
    base = parts[0]
    for i, p in enumerate(parts[1:], 1):
        if set(p.groups) != set(base.groups):
            raise ValueError(
                f"per-process index {i} disagrees on the group set: "
                f"{sorted(p.groups)} != {sorted(base.groups)}")
        if p.step != base.step:
            raise ValueError(
                f"per-process index {i} is from step {p.step}, "
                f"rank 0's from {base.step} -- torn pod save")
    groups: Dict[str, Dict[str, LeafEntry]] = {}
    for g, leaves in base.groups.items():
        out: Dict[str, LeafEntry] = {}
        for k, e in leaves.items():
            shards: List[ShardEntry] = []
            seen = set()
            for i, p in enumerate(parts):
                pe = p.groups[g].get(k)
                if pe is None:
                    raise ValueError(
                        f"{g}[{SEP}{k}]: missing from per-process "
                        f"index {i}")
                if (pe.shape, pe.dtype) != (e.shape, e.dtype):
                    raise ValueError(
                        f"{g}[{SEP}{k}]: fragment {i} disagrees on "
                        f"shape/dtype ({pe.shape}/{pe.dtype} != "
                        f"{e.shape}/{e.dtype})")
                for s in pe.shards:
                    sid = (s.file, s.key)
                    if sid not in seen:
                        seen.add(sid)
                        shards.append(s)
            out[k] = LeafEntry(e.shape, e.dtype, e.spec, tuple(shards))
        groups[g] = out
    return Manifest(step=base.step, extra=base.extra,
                    mesh_axes=base.mesh_axes, mesh_shape=base.mesh_shape,
                    groups=groups)


# ---------------------------------------------------------------------------
# Validation against a ``like`` pytree
# ---------------------------------------------------------------------------

def validate_like(entries: Dict[str, LeafEntry], like, group: str) -> None:
    """Every leaf of ``like`` must exist in the manifest with the same
    shape AND dtype; extra/missing keys are errors too.  Raises with the
    offending ``group[/key/path]`` (the silent-mismatch fix of ISSUE 4)."""
    flat_like = flatten_tree(like)
    if set(flat_like) != set(entries):
        missing = sorted(set(flat_like) - set(entries))
        extra = sorted(set(entries) - set(flat_like))
        raise ValueError(
            f"{group}: key mismatch (missing in checkpoint: "
            f"{missing[:5]}, unexpected in checkpoint: {extra[:5]})")
    for key, leaf in flat_like.items():
        e = entries[key]
        shape = tuple(np.shape(leaf))
        dtype = np.dtype(getattr(leaf, "dtype", np.asarray(leaf).dtype))
        if shape != e.shape:
            raise ValueError(
                f"{group}[{SEP}{key}]: checkpoint shape {e.shape} != "
                f"expected {shape}")
        if np.dtype(e.dtype) != dtype:
            raise ValueError(
                f"{group}[{SEP}{key}]: checkpoint dtype {e.dtype} != "
                f"expected {dtype.name}")
