"""Async checkpoint writer: hide checkpoint I/O behind training compute.

The same two-phase split as the input pipeline's prefetch thread
(``data/pipeline.py``), mirrored onto the output side:

  1. ``save()`` SYNCHRONOUSLY snapshots the addressable shards to host
     memory (``sharded.snapshot``) -- this must happen on the caller's
     thread, before the next train step donates/overwrites the device
     buffers -- then
  2. hands the Snapshot to a background thread that streams the shard
     files and manifest to disk while the train loop keeps stepping.

Guards:

  * at most ONE write is in flight: a second ``save()`` first waits for
    the previous write (bounding host memory to ~2 snapshots and
    keeping checkpoint directories internally consistent);
  * ``wait()`` is the barrier -- it joins the worker and re-raises any
    write error on the caller's thread (a failed checkpoint must not be
    silent);
  * transient ``OSError``s (an NFS blip, a full-but-draining disk) are
    retried with jittered exponential backoff (``retries`` attempts,
    DESIGN.md §12) before the error is surfaced at all -- a preemption
    save should not die on the first EIO of a node being reclaimed;
  * the writer is reusable after ``wait()``.
"""
from __future__ import annotations

import random
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from jax.sharding import Mesh

from repro import telemetry
from repro.checkpoint import sharded


class AsyncCheckpointWriter:
    """Background writer for sharded checkpoints.

    ``write_fn(snapshot, path)`` defaults to ``sharded.write_snapshot``
    and is injectable for tests (e.g. a slowed writer to assert the
    train loop genuinely overlaps the write).  ``retries``/
    ``retry_backoff`` bound the transient-``OSError`` retry loop
    (attempts total; backoff doubles per attempt, with jitter).
    """

    def __init__(self, write_fn: Optional[Callable] = None, *,
                 retries: int = 3, retry_backoff: float = 0.25):
        self._write_fn = write_fn or sharded.write_snapshot
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self._lock = threading.Lock()
        self.retries = max(1, int(retries))
        self.retry_backoff = retry_backoff
        self.saves = 0            # completed + in-flight submissions

    # -- state ----------------------------------------------------------
    @property
    def in_flight(self) -> bool:
        t = self._thread
        return t is not None and t.is_alive()

    # -- barrier --------------------------------------------------------
    def wait(self) -> None:
        """Block until the in-flight write (if any) finishes; re-raise
        its error here."""
        with self._lock:
            self._wait_locked()

    def _wait_locked(self) -> None:
        # caller holds self._lock; the worker never takes it, so joining
        # under the lock cannot deadlock
        t = self._thread
        if t is not None:
            t.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- the write itself ------------------------------------------------
    def _write_with_retry(self, snap: sharded.Snapshot, path: str,
                          kwargs: dict) -> None:
        """Run write_fn; retry transient OSErrors with jittered
        exponential backoff before re-raising (non-OSError failures are
        bugs, not weather -- they surface immediately)."""
        tr = telemetry.get_tracer()
        for attempt in range(1, self.retries + 1):
            try:
                with tr.span("ckpt.write", path=path, attempt=attempt):
                    return self._write_fn(snap, path, **kwargs)
            except OSError as e:
                if attempt >= self.retries:
                    raise
                tr.counter("ckpt.retries")
                tr.event("ckpt.retry", path=path, attempt=attempt,
                         error=repr(e))
                delay = (self.retry_backoff * (2 ** (attempt - 1))
                         * (1.0 + random.random()))
                print(f"[ckpt] transient write error on {path!r} "
                      f"(attempt {attempt}/{self.retries}): {e!r}; "
                      f"retrying in {delay:.2f}s")
                time.sleep(delay)

    # -- submission -----------------------------------------------------
    def save(self, path: str, groups: Dict[str, Any], *, step: int = 0,
             extra: Optional[dict] = None, mesh: Optional[Mesh] = None,
             block: bool = False,
             prune: Optional[List[str]] = None,
             process_index: int = 0,
             process_count: int = 1) -> sharded.Snapshot:
        """Snapshot ``groups`` now; write them in the background.

        Returns the Snapshot (its ``bytes_per_rank`` is the per-rank
        byte accounting asserted by the dist scenarios).  ``block=True``
        degrades to a synchronous save (the A/B baseline the ckpt_io
        benchmark measures against).

        ``prune`` lists older checkpoint directories to delete (the
        engine's keep-last-k GC) -- removed only AFTER this save's files
        are fully on disk, so an interrupted write never leaves the run
        with fewer durable checkpoints than before.

        ``process_index``/``process_count`` select the pod-scale write
        path (per-process shard index + rank-0 manifest merge,
        ``sharded.write_snapshot``); the defaults are the single-process
        behavior."""
        prune = list(prune or [])
        kwargs = ({} if process_count <= 1
                  else {"process_index": process_index,
                        "process_count": process_count})
        with self._lock:
            self._wait_locked()               # in-flight guard
            snap = sharded.snapshot(groups, step=step, extra=extra,
                                    mesh=mesh)
            self.saves += 1
            if block:
                self._write_with_retry(snap, path, kwargs)
                self._prune(prune)
                return snap

            def work():
                try:
                    self._write_with_retry(snap, path, kwargs)
                    self._prune(prune)
                except BaseException as e:    # surfaced at next wait()
                    self._error = e

            self._thread = threading.Thread(
                target=work, name=f"ckpt-writer:{path}", daemon=True)
            self._thread.start()
            return snap

    @staticmethod
    def _prune(paths: List[str]) -> None:
        """Delete GC'd checkpoint dirs (missing ones are fine)."""
        if not paths:
            return
        with telemetry.get_tracer().span("ckpt.prune", n=len(paths)):
            for p in paths:
                shutil.rmtree(p, ignore_errors=True)
