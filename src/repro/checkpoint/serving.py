"""Read-only serving restore (DESIGN.md §13).

Training checkpoints are zero-redundancy sharded saves whose manifest
records the *saving* topology's PartitionSpecs.  Serving needs none of
that topology: only the ``params`` group, landed on whatever mesh the
serving fleet happens to have (usually data-only -- params replicated,
batch sharded), possibly at a different precision than training kept
its weights in.

``restore_serving_params`` is that path: it validates the checkpoint's
architecture against the engine's, restores ONLY ``params`` (never
``opt_state`` -- a serving process must not pay for Adam moments), lets
``sharded.restore_tree``'s spec refit replicate every training-sharded
axis the serving mesh lacks, and finally casts leaves to the serving
policy's dtypes (a bf16-trained checkpoint can serve fp32 and vice
versa; shapes are validated leaf-by-leaf, dtypes are converted).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.checkpoint.manifest import Manifest, load_manifest
from repro.checkpoint.sharded import restore_tree


def _cast_like(params, like):
    """Validate shapes against ``like`` and cast dtypes to its leaves.

    ``like`` is typically ``jax.eval_shape(M.init, ...)`` under the
    SERVING config, so a precision mismatch between checkpoint and
    serving policy becomes a cast here instead of a restore error.
    """
    import jax

    def fit(path, leaf, ref):
        key = jax.tree_util.keystr(path)
        if tuple(leaf.shape) != tuple(ref.shape):
            raise ValueError(
                f"serving restore: param {key} shape {tuple(leaf.shape)} "
                f"!= model shape {tuple(ref.shape)} -- wrong config for "
                "this checkpoint?")
        if leaf.dtype != ref.dtype:
            leaf = (leaf.astype(ref.dtype) if isinstance(leaf, jax.Array)
                    else np.asarray(leaf, ref.dtype))
        return leaf

    try:
        return jax.tree_util.tree_map_with_path(fit, params, like)
    except ValueError as e:
        if "serving restore" in str(e):
            raise
        raise ValueError(
            f"serving restore: checkpoint param tree does not match the "
            f"model's ({e})") from e


def restore_serving_params(path: str, *, arch: Optional[str] = None,
                           like=None, mesh=None, specs=None
                           ) -> Tuple[object, Manifest]:
    """Restore a training checkpoint's params for serving.

    path : sharded checkpoint directory (any saving topology).
    arch : expected arch id; mismatches against the manifest raise
           (checkpoints predating the ``arch`` extra pass through).
    like : optional params pytree/ShapeDtypeStructs under the SERVING
           config -- shapes validated, dtypes cast (see ``_cast_like``).
    mesh : serving mesh (None -> host numpy).  The manifest's saving
           specs are refit onto it: axes it lacks replicate, so an
           8-way training save lands on ANY serving shape.
    specs: optional spec override (forwarded to ``restore_tree``).

    Returns ``(params, manifest)`` -- the manifest carries training
    metadata (step, precision, scheme) for logging/validation.
    """
    man = load_manifest(path)
    if "params" not in man.groups:
        raise ValueError(f"serving restore: {path!r} has no 'params' group "
                         f"(groups: {sorted(man.groups)})")
    ck_arch = man.extra.get("arch")
    if arch is not None and ck_arch is not None and ck_arch != arch:
        raise ValueError(f"serving restore: checkpoint arch {ck_arch!r} "
                         f"!= serving arch {arch!r}")
    params = restore_tree(path, "params", mesh=mesh, specs=specs,
                          manifest=man)
    if like is not None:
        params = _cast_like(params, like)
    return params, man
