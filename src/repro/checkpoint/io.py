"""Legacy checkpoint facade over the sharded subsystem.

``save``/``restore`` keep the original (path, params, opt_state, step)
signature the engine and older tests were written against, but the
storage underneath is the zero-redundancy sharded format of
``repro.checkpoint.sharded``: per-rank shard files + ``manifest.json``
-- no full-model ``device_get`` ever happens (the old implementation
gathered the whole pytree onto one host and blocked on a compressed
npz write; see DESIGN.md §9 for why that is exactly the anti-pattern
the paper's I/O analysis warns about).

``restore`` validates EVERY leaf of ``like_params`` / ``like_opt``
against the manifest -- shape and dtype -- and raises naming the
offending key path (mismatches used to be silently ignored).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

from repro.checkpoint import sharded
from repro.checkpoint.manifest import SEP, load_manifest  # noqa: F401


def save(path: str, params, opt_state=None, step: int = 0,
         extra: dict = None) -> None:
    """Sharded, synchronous save (the engine uses the async writer; this
    facade is the simple blocking entry point)."""
    groups: Dict[str, Any] = {"params": params}
    if opt_state is not None:
        groups["opt_state"] = opt_state
    sharded.save_checkpoint(path, groups, step=step, extra=extra)


def restore(path: str, like_params=None, like_opt=None, mesh=None,
            specs=None) -> Tuple[Any, Any, int]:
    """Returns (params, opt_state, step).

    ``like_*`` pytrees are validated leaf-by-leaf (shape AND dtype;
    errors name the offending key path).  With ``mesh`` the leaves land
    as jax.Arrays sharded on that mesh (saved specs refit to it, or
    ``specs`` overrides); without it they are plain numpy arrays."""
    man = load_manifest(path)
    params = sharded.restore_tree(path, "params", like=like_params,
                                  mesh=mesh, specs=specs, manifest=man)
    opt_state = None
    if "opt_state" in man.groups:
        opt_state = sharded.restore_tree(path, "opt_state", like=like_opt,
                                         mesh=mesh, specs=specs,
                                         manifest=man)
    return params, opt_state, man.step
