"""Checkpointing: pytree <-> npz with path-flattened keys.

Layout mirrors the zero-redundancy philosophy: ``save`` can write one
file per top-level group (params/opt/meta) so shards stream
independently; on a real pod each host would write its own slice -- here
(single host) we serialize the addressable arrays.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np

SEP = "/"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{SEP}"))
        return out
    out[prefix.rstrip(SEP)] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]):
    tree: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split(SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


def save(path: str, params, opt_state=None, step: int = 0,
         extra: dict = None) -> None:
    os.makedirs(path, exist_ok=True)
    np.savez_compressed(os.path.join(path, "params.npz"),
                        **_flatten(jax.device_get(params)))
    if opt_state is not None:
        np.savez_compressed(os.path.join(path, "opt_state.npz"),
                            **_flatten(jax.device_get(opt_state)))
    meta = {"step": int(step), **(extra or {})}
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f)


def restore(path: str, like_params=None, like_opt=None
            ) -> Tuple[Any, Any, int]:
    """Returns (params, opt_state, step).  If ``like_*`` pytrees are given,
    shapes/dtypes are validated against them."""
    flat = dict(np.load(os.path.join(path, "params.npz")))
    params = _unflatten(flat)
    opt_state = None
    opt_path = os.path.join(path, "opt_state.npz")
    if os.path.exists(opt_path):
        opt_state = _unflatten(dict(np.load(opt_path)))
    with open(os.path.join(path, "meta.json")) as f:
        step = json.load(f)["step"]

    def check(like, got, name):
        flat_like = _flatten(jax.device_get(like))
        flat_got = _flatten(got)
        if set(flat_like) != set(flat_got):
            missing = set(flat_like) ^ set(flat_got)
            raise ValueError(f"{name}: key mismatch {sorted(missing)[:5]}")
        for k, v in flat_like.items():
            if v.shape != flat_got[k].shape:
                raise ValueError(
                    f"{name}[{k}]: shape {flat_got[k].shape} != {v.shape}")

    if like_params is not None:
        check(like_params, params, "params")
    if like_opt is not None and opt_state is not None:
        check(like_opt, opt_state, "opt_state")
    return params, opt_state, step
