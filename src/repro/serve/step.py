"""Serving: prefill + batched decode steps.

``make_serve_step`` builds the single-token decode function the
decode_32k / long_500k dry-run shapes lower (one new token against a
seq_len-sized cache); ``generate`` drives it for the runnable examples.

ISSUE 8 changes:
  * ``prefill`` is now the fused single-``apply`` path -- one teacher-
    forced forward captures every layer's K/V and writes the cache back
    in O(1) applies instead of O(S) decode steps
    (``transformer.prefill_cache``).  The token-wise loop survives as
    ``prefill_tokenwise``, the eager interpret-mode reference the parity
    tests compare against, and the automatic fallback for families
    without a fused path (audio enc-dec, ssm/hybrid, local:global
    stacks).
  * the decode step is jit-CACHED (one executable per (cfg, jcfg),
    both frozen/hashable) and DONATES the cache pytree, so each step
    updates the KV buffers in place instead of copying the whole cache,
    and repeated ``generate`` calls never re-jit.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.api import JigsawConfig
from repro.models import registry as M


def make_serve_step(cfg: ModelConfig, jcfg: JigsawConfig,
                    greedy: bool = True):
    """Returns serve_step(params, cache, tokens[B,1]) ->
    (next_tokens [B,1], cache)."""

    def serve_step(params, cache, tokens):
        logits, cache = M.decode_step(params, cache, tokens, cfg, jcfg)
        # mask vocab padding before sampling
        logits = logits[..., : cfg.vocab_size]
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return nxt, cache

    return serve_step


@lru_cache(maxsize=None)
def jit_serve_step(cfg: ModelConfig, jcfg: JigsawConfig):
    """Compile-once decode step, cached by (cfg, jcfg).

    The cache pytree (arg 1) is DONATED: XLA reuses its buffers for the
    updated cache, so one decode step allocates O(new tokens), not
    O(cache) -- and because the wrapper itself is cached, repeated
    ``generate`` calls hit the same executable instead of re-jitting a
    fresh closure per call (the seed-era behavior)."""
    return jax.jit(make_serve_step(cfg, jcfg), donate_argnums=(1,))


def prefill_tokenwise(params, prompts: jax.Array, cfg: ModelConfig,
                      jcfg: JigsawConfig, max_len: int,
                      cache_dtype=jnp.bfloat16,
                      extra_batch: Optional[dict] = None):
    """Token-by-token prefill through ``decode_step`` -- the eager
    (interpret-mode) reference path: slow, but byte-for-byte the decode
    semantics, so the fused path asserts parity against it."""
    b, s = prompts.shape
    cache = M.init_cache(cfg, b, max_len, dtype=cache_dtype)
    if cfg.family == "audio" and extra_batch is not None:
        from repro.models import encdec
        cache["enc"] = encdec.encode(params, extra_batch["frames"], cfg,
                                     jcfg).astype(cache["enc"].dtype)
    step = make_serve_step(cfg, jcfg)
    last = prompts[:, :1]
    for t in range(s):
        last, cache = step(params, cache, prompts[:, t:t + 1])
    return last, cache


def prefill(params, prompts: jax.Array, cfg: ModelConfig,
            jcfg: JigsawConfig, max_len: int, cache_dtype=jnp.bfloat16,
            extra_batch: Optional[dict] = None,
            fused: Optional[bool] = None):
    """Fill a fresh cache from the prompt.

    fused=None (default) uses the fused single-``apply`` prefill when
    the family supports it and falls back token-wise otherwise;
    True forces fused (raises for unsupported families); False forces
    the token-wise reference."""
    if cfg.family == "audio" or extra_batch is not None:
        if fused:
            raise NotImplementedError("fused prefill: no enc-dec support")
        fused = False
    if fused is False:
        return prefill_tokenwise(params, prompts, cfg, jcfg, max_len,
                                 cache_dtype, extra_batch)
    try:
        logits, cache = M.prefill_cache(params, {"tokens": prompts}, cfg,
                                        jcfg, max_len, dtype=cache_dtype)
    except NotImplementedError:
        if fused:
            raise
        return prefill_tokenwise(params, prompts, cfg, jcfg, max_len,
                                 cache_dtype, extra_batch)
    nxt = jnp.argmax(logits[:, -1:, : cfg.vocab_size],
                     axis=-1).astype(jnp.int32)
    return nxt, cache


def generate(params, prompts: jax.Array, cfg: ModelConfig,
             jcfg: JigsawConfig, *, steps: int, max_len: int,
             extra_batch: Optional[dict] = None,
             fused: Optional[bool] = None) -> jax.Array:
    """Greedy generation: prefill then ``steps`` decode steps.

    The decode loop donates the cache each step and keeps every output
    token on device (one concatenate at the end) -- no per-step host
    round-trips."""
    nxt, cache = prefill(params, prompts, cfg, jcfg, max_len,
                         extra_batch=extra_batch, fused=fused)
    step = jit_serve_step(cfg, jcfg)
    out = [nxt]
    for _ in range(steps - 1):
        nxt, cache = step(params, cache, nxt)
        out.append(nxt)
    return jnp.concatenate(out, axis=1)
