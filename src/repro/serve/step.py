"""Serving: prefill + batched decode steps.

``make_serve_step`` builds the single-token decode function the
decode_32k / long_500k dry-run shapes lower (one new token against a
seq_len-sized cache), and ``generate`` drives it for the runnable
examples.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.api import JigsawConfig
from repro.models import registry as M


def make_serve_step(cfg: ModelConfig, jcfg: JigsawConfig,
                    greedy: bool = True):
    """Returns serve_step(params, cache, tokens[B,1]) ->
    (next_tokens [B,1], cache)."""

    def serve_step(params, cache, tokens):
        logits, cache = M.decode_step(params, cache, tokens, cfg, jcfg)
        # mask vocab padding before sampling
        logits = logits[..., : cfg.vocab_size]
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return nxt, cache

    return serve_step


def prefill(params, prompts: jax.Array, cfg: ModelConfig,
            jcfg: JigsawConfig, max_len: int, cache_dtype=jnp.bfloat16,
            extra_batch: Optional[dict] = None):
    """Fill a fresh cache by decoding the prompt token-by-token.

    (A fused prefill via ``apply`` + cache write-back is the production
    path on TPU; token-wise prefill keeps the CPU example simple and
    exercises the same decode_step the dry-run lowers.)
    """
    b, s = prompts.shape
    cache = M.init_cache(cfg, b, max_len, dtype=cache_dtype)
    if cfg.family == "audio" and extra_batch is not None:
        from repro.models import encdec
        cache["enc"] = encdec.encode(params, extra_batch["frames"], cfg,
                                     jcfg).astype(cache["enc"].dtype)
    step = make_serve_step(cfg, jcfg)
    last = prompts[:, :1]
    for t in range(s):
        last, cache = step(params, cache, prompts[:, t:t + 1])
    return last, cache


def generate(params, prompts: jax.Array, cfg: ModelConfig,
             jcfg: JigsawConfig, *, steps: int, max_len: int,
             extra_batch: Optional[dict] = None) -> jax.Array:
    """Greedy generation: prefill then ``steps`` decode steps."""
    nxt, cache = prefill(params, prompts, cfg, jcfg, max_len,
                         extra_batch=extra_batch)
    step = jax.jit(make_serve_step(cfg, jcfg))
    out = [nxt]
    for _ in range(steps - 1):
        nxt, cache = step(params, cache, nxt)
        out.append(nxt)
    return jnp.concatenate(out, axis=1)
