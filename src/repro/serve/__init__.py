"""Serving subsystem (DESIGN.md §13).

* ``engine``    -- ForecastEngine: continuous-batching autoregressive
                   field-rollout serving (jit compile-cache per batch
                   bucket, donated state, restore-onto-serving-mesh).
* ``scheduler`` -- host-side microbatch policy (coalescing, step-
                   boundary admission, bucket growth, lead fan-out).
* ``step``      -- token-LM serving: fused prefill + donated-cache
                   greedy decode through ``decode_step``.
"""
from repro.serve.engine import ForecastEngine, ServeConfig  # noqa: F401
from repro.serve.scheduler import (ForecastResult,  # noqa: F401
                                   MicrobatchScheduler)
