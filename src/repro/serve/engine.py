"""Continuous-batching forecast serving engine (DESIGN.md §13).

The production inference path for the autoregressive WeatherMixer
rollout.  One ``ForecastEngine`` owns:

  * the serving mesh -- data-only (``model=1``), any shape: params
    restored from an arbitrary-topology training checkpoint replicate
    onto it via the sharded-restore spec refit
    (``checkpoint/serving.py``);
  * a *compile-cache* of jitted device functions, one set per padded
    batch bucket -- the rollout ``step`` (state donated: the forecast
    overwrites its own buffer), ``admit`` (dynamic row write of a new
    request's initial condition, state donated), ``peel`` (dynamic row
    read of a finished lead), ``zeros`` (fresh state) and adjacent
    bucket ``grow`` (pad) fns.  After ``warmup()`` steady-state serving
    performs ZERO compiles: every function traces exactly once per
    bucket, counted by ``stats["compiles"]`` (incremented at trace time,
    so retraces are caught), and asserted by
    ``benchmarks/serve_throughput.py``;
  * a ``MicrobatchScheduler`` (serve/scheduler.py) deciding, at every
    rollout-step boundary, which queued requests to admit into free
    slots (continuous batching), when to coalesce, grow, or -- in the
    ``drain`` baseline mode -- wait for the batch to empty.

Requests are ``submit()``-ed (thread-safe) and return future-style
``ForecastResult`` handles; ``drain()`` (or the ``start()`` background
thread) advances boundaries until the queue empties.  Different lead
times share one rollout and peel off at their own step.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from functools import partial
from typing import Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import telemetry
from repro.configs.registry import get_config
from repro.core import precision
from repro.launch import shapes as SH
from repro.launch import specs as S
from repro.launch.mesh import make_host_mesh
from repro.models import registry as M
from repro.serve.scheduler import (ForecastResult, MicrobatchScheduler,
                                   Lead)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Serving policy knobs (the engine ctor takes the topology)."""
    buckets: Tuple[int, ...] = (1, 2, 4, 8)
    mode: str = "continuous"          # | "drain" (static-batching baseline)
    coalesce_s: float = 0.0           # idle burst-coalescing window
    precision: Optional[str] = None   # serving policy preset (may differ
    seed: int = 0                     # from the checkpoint's)
    telemetry: bool = True            # span tracing (histograms stay live)
    trace: Optional[str] = None       # Chrome trace export path

    def replace(self, **kw) -> "ServeConfig":
        return dataclasses.replace(self, **kw)


class ForecastEngine:
    """Batched autoregressive forecast serving over a data-only mesh."""

    def __init__(self, arch: str, *, reduced: bool = True,
                 ckpt: Optional[str] = None, params=None,
                 mesh_data: int = 1, config: ServeConfig = ServeConfig(),
                 config_override=None, clock=time.monotonic):
        self.arch = arch
        self.config = config
        cfg = config_override if config_override is not None \
            else get_config(arch)
        if reduced:
            cfg = cfg.reduced()
        # serving is data-parallel only: every rank holds the full model
        # and the whole Jigsaw contraction is local (scheme="none")
        cfg = cfg.replace(scheme="none", impl="rs")
        if config.precision:
            cfg = precision.apply_policy(cfg, config.precision)
        self.policy = precision.policy_of(cfg)
        if cfg.family != "mixer":
            raise ValueError(
                f"ForecastEngine drives the autoregressive field rollout; "
                f"{arch} is family {cfg.family!r} (use serve.step for "
                "token decoding)")
        self.cfg = cfg
        self.jcfg = SH.jigsaw_for(cfg)
        self.field_shape = (cfg.wm_lat, cfg.wm_lon, cfg.wm_channels)

        self.mesh = (make_host_mesh(model=1, data=mesh_data)
                     if mesh_data > 1 else None)
        self.stats = {"compiles": 0, "device_steps": 0, "wait_ticks": 0,
                      "warmup_s": 0.0}
        # engine-local tracer: admission-to-delivery histograms (one per
        # lead time) + serve spans; not the process tracer, so several
        # engines in one process (A/B benchmarks) never mix percentiles
        self.tracer = telemetry.Tracer(enabled=config.telemetry)
        self.tracer.set_meta(surface="serve", arch=arch, reduced=reduced,
                             mesh_data=mesh_data, mode=config.mode,
                             buckets=list(config.buckets))
        self.sched = MicrobatchScheduler(
            config.buckets, mode=config.mode,
            coalesce_s=config.coalesce_s, clock=clock)
        self._clock = clock
        self._sleep = time.sleep

        # -- params: restore > passed-in > fresh init ----------------------
        like = jax.eval_shape(partial(M.init, cfg=cfg),
                              jax.random.PRNGKey(config.seed))
        self.restored_step = None
        if ckpt is not None:
            from repro.checkpoint.serving import restore_serving_params
            params, man = restore_serving_params(
                ckpt, arch=arch, like=like, mesh=self.mesh)
            self.restored_step = man.step
        elif params is None:
            params = M.init(jax.random.PRNGKey(config.seed), cfg)
        else:
            # the step never donates params, but cast to the serving policy
            params = jax.tree.map(
                lambda l, r: jnp.asarray(l, r.dtype), params, like)
        if self.mesh is not None and ckpt is None:
            params = jax.device_put(
                params, NamedSharding(self.mesh, P()))  # replicate
        self.params = params

        self._row_sharding = (NamedSharding(self.mesh, P())
                              if self.mesh is not None else None)
        self._bucket_fns = {}       # bucket -> {step, admit, peel, zeros}
        self._grow_fns = {}         # (b_from, b_to) -> jitted pad
        self._state = None
        self._bucket = 0
        self._stop = threading.Event()
        self._wake = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- compile-cache -----------------------------------------------------
    def _state_sharding(self, b: int):
        if self.mesh is None:
            return None
        spec = S.sanitize_spec((b, *self.field_shape), P("data"), self.mesh)
        return NamedSharding(self.mesh, spec)

    def _count(self, name: str) -> None:
        # called from INSIDE jitted bodies: runs at trace time only, so
        # it counts (re)compiles, not executions
        self.stats[name] += 1

    def _fns(self, b: int):
        if b in self._bucket_fns:
            return self._bucket_fns[b]
        shape = (b, *self.field_shape)
        sh = self._state_sharding(b)
        pin = (lambda x: x) if sh is None else \
            (lambda x: jax.lax.with_sharding_constraint(x, sh))

        def _step(params, state):
            self._count("compiles")
            return pin(M.forecast_step(params, state, self.cfg, self.jcfg))

        def _admit(state, fields, slot):
            self._count("compiles")
            row = fields.astype(state.dtype)[None]
            return pin(jax.lax.dynamic_update_index_in_dim(
                state, row, slot, 0))

        def _peel(state, slot):
            self._count("compiles")
            return jax.lax.dynamic_index_in_dim(state, slot, 0,
                                                keepdims=False)

        def _zeros():
            self._count("compiles")
            return pin(jnp.zeros(shape, jnp.float32))

        fns = {"step": jax.jit(_step, donate_argnums=(1,)),
               "admit": jax.jit(_admit, donate_argnums=(0,)),
               "peel": jax.jit(_peel),
               "zeros": jax.jit(_zeros)}
        self._bucket_fns[b] = fns
        return fns

    def _grow(self, b_from: int, b_to: int):
        key = (b_from, b_to)
        if key not in self._grow_fns:
            sh = self._state_sharding(b_to)
            pin = (lambda x: x) if sh is None else \
                (lambda x: jax.lax.with_sharding_constraint(x, sh))

            def _pad(state):
                self._count("compiles")
                return pin(jnp.pad(
                    state, ((0, b_to - b_from),) + ((0, 0),) * 3))

            # no donation: the padded output is LARGER than the input, so
            # XLA could never alias the buffers (it would only warn)
            self._grow_fns[key] = jax.jit(_pad)
        return self._grow_fns[key]

    def compile_cache_size(self) -> int:
        """Executables held by the jit caches (cross-check for the trace
        counter; jax internal, so best-effort)."""
        fns = [f for d in self._bucket_fns.values() for f in d.values()]
        fns += list(self._grow_fns.values())
        try:
            return sum(f._cache_size() for f in fns)
        except AttributeError:      # older/newer jaxlib
            return -1

    def warmup(self, buckets: Optional[Sequence[int]] = None) -> int:
        """Pre-compile every bucket's step/admit/peel/zeros (+ adjacent
        grows) with dummy states so steady-state serving never traces.
        Returns the compile count, also stamped into
        ``stats["warm_compiles"]`` -- the benchmark's zero-recompile
        assertion compares against it."""
        t0 = time.perf_counter()
        buckets = tuple(sorted(buckets or self.config.buckets))
        dummy = self._put_fields(np.zeros(self.field_shape, np.float32))
        for b in buckets:
            fns = self._fns(b)
            state = fns["zeros"]()
            state = fns["admit"](state, dummy, np.int32(0))
            state = fns["step"](self.params, state)
            np.asarray(fns["peel"](state, np.int32(0)))
        for b1, b2 in zip(buckets, buckets[1:]):
            self._grow(b1, b2)(self._fns(b1)["zeros"]())
        self.stats["warmup_s"] += time.perf_counter() - t0
        self.stats["warm_compiles"] = self.stats["compiles"]
        return self.stats["compiles"]

    # -- request path ------------------------------------------------------
    def _put_fields(self, fields: np.ndarray):
        if self._row_sharding is not None:
            return jax.device_put(fields, self._row_sharding)
        return jax.device_put(fields)

    def submit(self, fields, lead: Lead = 1) -> ForecastResult:
        """Enqueue one forecast request (thread-safe).

        fields: [lat, lon, C] initial condition.  lead: rollout steps
        ahead -- an int, or a sequence of horizons that share the rollout
        and peel off at their own step (lead-time fan-out)."""
        leads = (int(lead),) if np.isscalar(lead) else \
            tuple(sorted(set(int(x) for x in lead)))
        if not leads or leads[0] < 1:
            raise ValueError(f"leads must be >= 1, got {leads}")
        fields = np.asarray(fields, np.float32)
        if fields.shape != self.field_shape:
            raise ValueError(f"fields shape {fields.shape} != "
                             f"{self.field_shape}")
        req = ForecastResult(fields, leads, submit_t=self._clock())
        self.sched.submit(req)
        self._wake.set()
        return req

    def step_once(self) -> str:
        """Advance one rollout-step boundary.

        Returns "idle" (nothing to do), "wait" (coalescing window still
        open) or "step" (one device rollout step ran)."""
        tick = self.sched.tick()
        if tick.idle:
            return "idle"
        if tick.wait is not None:
            self.stats["wait_ticks"] += 1
            return "wait"
        tr = self.tracer
        if tick.form is not None:
            with tr.span("serve.form", bucket=tick.form):
                self._state = self._fns(tick.form)["zeros"]()
            self._bucket = tick.form
        elif tick.grow is not None:
            with tr.span("serve.grow", b_from=self._bucket,
                         b_to=tick.grow):
                self._state = self._grow(self._bucket,
                                         tick.grow)(self._state)
            self._bucket = tick.grow
        fns = self._fns(self._bucket)
        if tick.admit:
            with tr.span("serve.admit", n=len(tick.admit),
                         bucket=self._bucket):
                for slot, req in tick.admit:
                    self._state = fns["admit"](self._state,
                                               self._put_fields(req.fields),
                                               np.int32(slot))
        with tr.span("serve.step", bucket=self._bucket):
            self._state = fns["step"](self.params, self._state)
        self.stats["device_steps"] += 1
        tr.counter("serve.device_steps")
        peels, _finished = self.sched.advance()
        now = self._clock()
        for slot, req, lead in peels:
            with tr.span("serve.peel", lead=lead):
                out = np.asarray(fns["peel"](self._state, np.int32(slot)))
            req.deliver(lead, out, now)
            # admission-to-delivery latency histograms: the engine's
            # serving SLO, one track per lead time plus the overall one
            lat = now - req.submit_t
            tr.observe("serve.latency_s", lat)
            tr.observe(f"serve.latency_s/lead={lead}", lat)
        return "step"

    def drain(self, poll_s: float = 1e-3) -> None:
        """Run boundaries until queue and batch are empty."""
        while True:
            r = self.step_once()
            if r == "idle":
                return
            if r == "wait":
                self._sleep(poll_s)

    def serve(self, fields_batch, leads: Sequence[Lead]):
        """Convenience: submit a batch of requests and drain."""
        out = [self.submit(f, ld) for f, ld in zip(fields_batch, leads)]
        self.drain()
        return out

    # -- background serving loop (for live submitters, e.g. the CLI) ------
    def start(self) -> None:
        if self._thread is not None:
            raise RuntimeError("engine already started")
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                r = self.step_once()
                if r == "idle":
                    self._wake.wait(0.005)
                    self._wake.clear()
                elif r == "wait":
                    self._sleep(1e-3)

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="forecast-serve")
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._wake.set()
        self._thread.join()
        self._thread = None

    # -- reporting ---------------------------------------------------------
    def summary(self, results: Sequence[ForecastResult]) -> dict:
        """Serving report over everything this engine delivered: the
        admission-to-delivery percentiles come from the engine's
        telemetry histograms (p50/p95/p99 overall and per lead time),
        not a private sort of ``results``."""
        h = self.tracer.hist_summary("serve.latency_s")
        nan = float("nan")
        sc = self.sched.counters
        leads = {}
        for name in self.tracer.hist_names():
            if name.startswith("serve.latency_s/lead="):
                lead = int(name.split("=", 1)[1])
                leads[lead] = self.tracer.hist_summary(name)
        return {"requests": len(results),
                "p50_s": h.get("p50", nan), "p95_s": h.get("p95", nan),
                "p99_s": h.get("p99", nan),
                "deliveries": h.get("count", 0),
                "lead_latency_s": leads,
                "device_steps": self.stats["device_steps"],
                "compiles": self.stats["compiles"],
                "admitted": sc["admitted"], "completed": sc["completed"],
                "formed": sc["formed"], "grown": sc["grown"]}

    def export_trace(self, path: Optional[str] = None) -> Optional[str]:
        """Write this engine's Chrome trace (+ sibling JSONL) to
        ``path`` or ``config.trace``; returns the path (None = no-op)."""
        path = path or self.config.trace
        if not path:
            return None
        self.tracer.export_chrome(path)
        self.tracer.export_jsonl(telemetry.jsonl_path_for(path))
        return path
