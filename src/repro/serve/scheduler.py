"""Request scheduling for the forecast serving engine (DESIGN.md §13).

Host-side policy only -- this module never imports jax -- so every
scheduling decision (coalescing, continuous admission, bucket growth,
lead-time fan-out) is unit-testable with a fake clock, and the engine
(``serve/engine.py``) owns every device interaction.

The scheduler advances in *rollout-step boundaries*: one ``tick()``
decides what happens before the next autoregressive model step (form or
grow the batch, admit queued requests into free slots, or wait out the
coalescing window), the engine runs the device step, and ``advance()``
then ages every in-flight request, returning which slots must be peeled
(a requested lead time was reached) and which are finished and freed.

Why admission only at step boundaries: every request in the batch shares
ONE jitted rollout step, so the only points where the batch composition
may change without tearing that step apart are between applications of
it.  Admitting there costs a single O(fields) dynamic-update on the
donated state buffer; admitting mid-step would mean either recompiling
(new batch shape) or re-running the partial step (wasted compute).
Draining instead (classic static batching) makes every request wait for
the slowest lead time in its batch -- the continuous-vs-drain benchmark
(benchmarks/serve_throughput.py) measures exactly that gap.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple, Union

Lead = Union[int, Sequence[int]]

_RID = itertools.count()


class ForecastResult:
    """Future-style handle for one submitted forecast request.

    ``leads`` may name several horizons: the request occupies ONE batch
    slot for ``max_lead`` rollout steps and *peels off* an output at
    each requested lead (lead-time fan-out) -- intermediate horizons
    are free, they ride the same rollout.
    """

    def __init__(self, fields, leads: Tuple[int, ...], submit_t: float):
        self.fields = fields                     # host array [lat, lon, C]
        self.leads = leads                       # sorted, unique, >= 1
        self.rid = next(_RID)
        self.submit_t = submit_t
        self.start_t: Optional[float] = None     # admission time
        self.done_t: Optional[float] = None
        self.outputs: Dict[int, object] = {}     # lead -> fields array
        self._event = threading.Event()

    @property
    def max_lead(self) -> int:
        return self.leads[-1]

    def deliver(self, lead: int, out, now: float) -> None:
        self.outputs[lead] = out
        if lead == self.max_lead:
            self.done_t = now
            self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None):
        """Block until the final lead is delivered; returns its fields."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.rid} not done")
        return self.outputs[self.max_lead]

    def output(self, lead: int):
        return self.outputs[lead]

    def latency(self) -> float:
        return self.done_t - self.submit_t

    def queue_delay(self) -> float:
        return self.start_t - self.submit_t


class _Slot:
    __slots__ = ("req", "age")

    def __init__(self, req: ForecastResult):
        self.req = req
        self.age = 0          # rollout steps taken since admission


class Tick:
    """One boundary's worth of instructions for the engine."""
    __slots__ = ("wait", "form", "grow", "admit", "step")

    def __init__(self, *, wait: Optional[float] = None,
                 form: Optional[int] = None, grow: Optional[int] = None,
                 admit: Optional[List[Tuple[int, ForecastResult]]] = None,
                 step: bool = False):
        self.wait = wait        # seconds left in the coalescing window
        self.form = form        # build a fresh state at this bucket
        self.grow = grow        # pad the live state up to this bucket
        self.admit = admit or []  # [(slot index, request)]
        self.step = step        # run the device rollout step

    @property
    def idle(self) -> bool:
        return (self.wait is None and self.form is None
                and self.grow is None and not self.admit and not self.step)


class MicrobatchScheduler:
    """Continuous-batching policy over padded batch buckets.

    * ``buckets``: ascending padded batch sizes; the jitted rollout step
      is compiled once per bucket and reused (see engine).  A batch of n
      live requests runs at ``bucket_for(n)`` -- the smallest bucket
      >= n, or the largest bucket when oversubscribed (the rest queue).
    * ``mode="continuous"``: queued requests are admitted into free
      slots at every step boundary; the batch grows to the NEXT bucket
      (one hop per boundary, so only adjacent grow-fns ever compile)
      when full.  Shrinking happens only by re-forming after the batch
      empties -- compacting a live batch downward would buy nothing (the
      padded rows are free) and cost a gather.
    * ``mode="drain"``: classic static batching -- admission only into
      an EMPTY batch; the reference baseline the benchmark beats.
    * ``coalesce_s``: when idle, hold the first arrival this long (or
      until a full max-size bucket is queued) before forming a batch, so
      bursty traffic coalesces into one microbatch instead of n singleton
      batches.
    """

    def __init__(self, buckets: Sequence[int] = (1, 2, 4, 8), *,
                 mode: str = "continuous", coalesce_s: float = 0.0,
                 clock=time.monotonic):
        buckets = tuple(sorted(set(int(b) for b in buckets)))
        if not buckets or buckets[0] < 1:
            raise ValueError(f"bad buckets {buckets!r}")
        if mode not in ("continuous", "drain"):
            raise ValueError(f"unknown mode {mode!r} "
                             "(expected 'continuous' | 'drain')")
        self.buckets = buckets
        self.mode = mode
        self.coalesce_s = coalesce_s
        self.clock = clock
        self._lock = threading.Lock()
        self._queue: deque = deque()
        self._slots: List[Optional[_Slot]] = []
        self.counters = {"admitted": 0, "completed": 0, "formed": 0,
                         "grown": 0, "waited": 0}

    # -- introspection ----------------------------------------------------
    @property
    def bucket(self) -> int:
        return len(self._slots)

    @property
    def max_bucket(self) -> int:
        return self.buckets[-1]

    def pending(self) -> int:
        with self._lock:
            return len(self._queue)

    def active(self) -> int:
        with self._lock:
            return sum(s is not None for s in self._slots)

    def bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        return self.max_bucket

    # -- the boundary protocol --------------------------------------------
    def submit(self, req: ForecastResult) -> None:
        with self._lock:
            self._queue.append(req)

    def tick(self, now: Optional[float] = None) -> Tick:
        """Decide what happens at this rollout-step boundary."""
        with self._lock:
            now = self.clock() if now is None else now
            active = sum(s is not None for s in self._slots)
            if active == 0:
                self._slots = []          # collapse the drained batch
                if not self._queue:
                    return Tick()
                if (self.coalesce_s > 0
                        and len(self._queue) < self.max_bucket):
                    deadline = self._queue[0].submit_t + self.coalesce_s
                    if now < deadline:
                        self.counters["waited"] += 1
                        return Tick(wait=deadline - now)
                b = self.bucket_for(len(self._queue))
                self._slots = [None] * b
                self.counters["formed"] += 1
                return Tick(form=b, admit=self._admit_free(now), step=True)
            # a batch is in flight
            grow = None
            admits: List[Tuple[int, ForecastResult]] = []
            if self.mode == "continuous" and self._queue:
                if (all(s is not None for s in self._slots)
                        and self.bucket < self.max_bucket):
                    nxt = self.buckets[self.buckets.index(self.bucket) + 1]
                    self._slots.extend([None] * (nxt - self.bucket))
                    self.counters["grown"] += 1
                    grow = nxt
                admits = self._admit_free(now)
            return Tick(grow=grow, admit=admits, step=True)

    def _admit_free(self, now: float) -> List[Tuple[int, ForecastResult]]:
        admits = []
        for i, s in enumerate(self._slots):
            if s is None and self._queue:
                req = self._queue.popleft()
                req.start_t = now
                self._slots[i] = _Slot(req)
                admits.append((i, req))
                self.counters["admitted"] += 1
        return admits

    def advance(self):
        """Account one completed device step.

        Returns ``(peels, finished)``: ``peels`` = [(slot, request,
        lead)] whose outputs must be read off the state now (the engine
        delivers them), ``finished`` = [(slot, request)] freed at this
        boundary (their last lead was reached).
        """
        with self._lock:
            peels, finished = [], []
            for i, s in enumerate(self._slots):
                if s is None:
                    continue
                s.age += 1
                if s.age in s.req.leads:
                    peels.append((i, s.req, s.age))
                if s.age >= s.req.max_lead:
                    finished.append((i, s.req))
                    self._slots[i] = None
                    self.counters["completed"] += 1
            return peels, finished
