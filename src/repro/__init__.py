"""Jigsaw reproduction package.

Importing the package installs the JAX version-compatibility shims
(``repro.compat``) so modules written against the modern jax API run on
the pinned jax of this environment.
"""
from repro import compat  # noqa: F401  (side effect: compat.install())

__all__ = ["compat"]
