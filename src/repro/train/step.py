"""Train-step builders: loss -> grad -> clip -> Adam, per architecture
family.  Gradient reduction over the data/pod axes is implicit in SPMD
(params replicated over those axes), matching the paper's `r % n`
grouping: only same-shard ranks reduce together.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.api import JigsawConfig
from repro.models import registry as M
from repro.optim import adam, schedule as sched
from repro.train import loss as losses

AUX_WEIGHT = 0.01   # MoE load-balance loss weight


def loss_fn(params, batch, cfg: ModelConfig, jcfg: JigsawConfig,
            rollout: int = 1):
    """Returns (scalar loss, metrics dict)."""
    if cfg.family == "mixer":
        pred, aux = M.apply(params, batch, cfg, jcfg, rollout=rollout)
        lat_w = losses.latitude_weights(cfg.wm_lat)
        chan_w = losses.pressure_level_weights(cfg.wm_channels) \
            if cfg.wm_channels >= 69 else None
        main = losses.weighted_mse(pred, batch["target"], lat_w, chan_w)
        return main, {"loss": main, "mse": main}
    logits, aux = M.apply(params, batch, cfg, jcfg)
    labels = batch["labels"]
    if cfg.family == "vlm":
        # drop the vision-prefix positions; predict text only
        logits = logits[:, -labels.shape[1]:]
    nll = losses.lm_cross_entropy(logits, labels, cfg.vocab_size,
                                  mask=batch.get("mask"))
    total = nll + AUX_WEIGHT * aux
    return total, {"loss": total, "nll": nll, "aux": aux}


def make_train_step(cfg: ModelConfig, jcfg: JigsawConfig,
                    adam_cfg: adam.AdamConfig = adam.AdamConfig(),
                    lr_fn: Callable = None, rollout: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``rollout`` > 1 enables the paper's randomized-rollout fine-tuning
    (mixer only): the processor runs ``rollout`` times per update.
    """
    lr_fn = lr_fn or partial(sched.warmup_cosine)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg, jcfg, rollout)
        lr = lr_fn(opt_state["step"])
        new_params, new_opt = adam.update(params, grads, opt_state, lr,
                                          adam_cfg)
        metrics = dict(metrics, lr=lr,
                       grad_norm=adam.global_norm(grads))
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, jcfg: JigsawConfig, rollout: int = 1):
    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch, cfg, jcfg, rollout)
        return metrics
    return eval_step
