"""Train-step builders: loss -> grad -> clip -> Adam, per architecture
family.  Gradient reduction over the data/pod axes is implicit in SPMD
(params replicated over those axes), matching the paper's `r % n`
grouping: only same-shard ranks reduce together.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.api import JigsawConfig
from repro.models import registry as M
from repro.optim import adam, schedule as sched
from repro.train import loss as losses

AUX_WEIGHT = 0.01   # MoE load-balance loss weight


def loss_fn(params, batch, cfg: ModelConfig, jcfg: JigsawConfig,
            rollout: int = 1):
    """Returns (scalar loss, metrics dict)."""
    if cfg.family == "mixer":
        pred, aux = M.apply(params, batch, cfg, jcfg, rollout=rollout)
        lat_w = losses.latitude_weights(cfg.wm_lat)
        chan_w = losses.pressure_level_weights(cfg.wm_channels) \
            if cfg.wm_channels >= 69 else None
        main = losses.weighted_mse(pred, batch["target"], lat_w, chan_w)
        return main, {"loss": main, "mse": main}
    logits, aux = M.apply(params, batch, cfg, jcfg)
    labels = batch["labels"]
    if cfg.family == "vlm":
        # drop the vision-prefix positions; predict text only
        logits = logits[:, -labels.shape[1]:]
    nll = losses.lm_cross_entropy(logits, labels, cfg.vocab_size,
                                  mask=batch.get("mask"))
    total = nll + AUX_WEIGHT * aux
    return total, {"loss": total, "nll": nll, "aux": aux}


def make_train_step(cfg: ModelConfig, jcfg: JigsawConfig,
                    adam_cfg: adam.AdamConfig = adam.AdamConfig(),
                    lr_fn: Callable = None, rollout: int = 1,
                    accum: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``rollout`` > 1 enables the paper's randomized-rollout fine-tuning
    (mixer only): the processor runs ``rollout`` times per update.

    ``accum`` > 1 enables microbatch gradient accumulation: the batch's
    leading dim is split into ``accum`` consecutive microbatches scanned
    sequentially, gradients averaged in f32 before one optimizer update.
    Mathematically the full-batch update (losses are per-element means
    over equal-sized microbatches) at 1/accum the activation memory.
    """
    lr_fn = lr_fn or partial(sched.warmup_cosine)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def apply_update(params, opt_state, grads, metrics):
        lr = lr_fn(opt_state["step"])
        new_params, new_opt = adam.update(params, grads, opt_state, lr,
                                          adam_cfg)
        metrics = dict(metrics, lr=lr, grad_norm=adam.global_norm(grads))
        return new_params, new_opt, metrics

    if accum == 1:
        def train_step(params, opt_state, batch):
            (loss, metrics), grads = grad_fn(params, batch, cfg, jcfg,
                                             rollout)
            return apply_update(params, opt_state, grads, metrics)
        return train_step

    def train_step(params, opt_state, batch):
        def split(v):
            if v.shape[0] % accum != 0:
                raise ValueError(
                    f"batch dim {v.shape[0]} not divisible by accum={accum}")
            return v.reshape((accum, v.shape[0] // accum) + v.shape[1:])

        micro = jax.tree.map(split, batch)

        def body(gsum, mb):
            (_, metrics), grads = grad_fn(params, mb, cfg, jcfg, rollout)
            gsum = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), gsum, grads)
            return gsum, metrics

        gsum = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        gsum, stacked = jax.lax.scan(body, gsum, micro)
        grads = jax.tree.map(lambda g: g / accum, gsum)
        metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), stacked)
        return apply_update(params, opt_state, grads, metrics)

    return train_step


def make_eval_step(cfg: ModelConfig, jcfg: JigsawConfig, rollout: int = 1):
    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch, cfg, jcfg, rollout)
        return metrics
    return eval_step
