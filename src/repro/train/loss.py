"""Losses: latitude-weighted RMSE/MSE (weather, §6) + LM cross-entropy."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def latitude_weights(lat_points: int) -> jnp.ndarray:
    """cos(latitude) weights, normalized to mean 1 (WeatherBench2
    convention); grid rows span +90..-90 degrees."""
    lats = np.linspace(90.0, -90.0, lat_points)
    w = np.cos(np.deg2rad(lats))
    w = np.maximum(w, 0.0)
    w = w / w.mean()
    return jnp.asarray(w, jnp.float32)


def pressure_level_weights(channels: int, n_surface: int = 4,
                           n_vars: int = 5, n_levels: int = 13
                           ) -> jnp.ndarray:
    """The paper's meteorologically-grounded per-channel weights: surface
    variables (Bi et al. weights ~ 1) and, from high to low pressure
    levels, [1,1,1,1,1,1,.9,.8,.7,.6,.5,.4,.3] per variable."""
    lvl = np.array([1, 1, 1, 1, 1, 1, 0.9, 0.8, 0.7, 0.6, 0.5, 0.4, 0.3])
    w = np.ones(channels)
    for v in range(n_vars):
        lo = n_surface + v * n_levels
        hi = min(lo + n_levels, channels)
        w[lo:hi] = lvl[: hi - lo]
    return jnp.asarray(w, jnp.float32)


def weighted_mse(pred: jax.Array, target: jax.Array,
                 lat_w: Optional[jax.Array] = None,
                 chan_w: Optional[jax.Array] = None) -> jax.Array:
    """pred/target: [B, lat, lon, C]."""
    err = (pred.astype(jnp.float32) - target.astype(jnp.float32)) ** 2
    if lat_w is not None:
        err = err * lat_w[None, :, None, None]
    if chan_w is not None:
        err = err * chan_w[None, None, None, :]
    return jnp.mean(err)


def latitude_weighted_rmse(pred: jax.Array, target: jax.Array,
                           lat_w: Optional[jax.Array] = None) -> jax.Array:
    """Per-channel lat-weighted RMSE [C] (the paper's evaluation metric)."""
    err = (pred.astype(jnp.float32) - target.astype(jnp.float32)) ** 2
    if lat_w is None:
        lat_w = latitude_weights(pred.shape[1])
    err = err * lat_w[None, :, None, None]
    return jnp.sqrt(jnp.mean(err, axis=(0, 1, 2)))


def lm_cross_entropy(logits: jax.Array, labels: jax.Array,
                     vocab_size: int,
                     mask: Optional[jax.Array] = None) -> jax.Array:
    """logits: [B, S, Vp] (Vp >= vocab_size; padded ids masked out),
    labels: [B, S] int32.  Mean NLL over unmasked positions.

    Implementation note: everything is element-wise + reductions over the
    vocab dim (iota compares instead of dynamic-slice / gather), so a
    vocab-sharded logits tensor stays sharded -- gather/updateslice at
    unaligned offsets makes GSPMD replicate the full [B, S, V] tensor
    (~360 GiB/device at train_4k scale).
    """
    vp = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    vocab_ids = jax.lax.broadcasted_iota(jnp.int32, (vp,), 0)
    if vp > vocab_size:
        logits = logits + jnp.where(vocab_ids >= vocab_size, -1e30, 0.0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = (vocab_ids[None, None, :] == labels[..., None])
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
