"""LR schedules (paper §6: linear warm-up then cosine decay to 1e-5)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, base_lr: float = 1e-4, warmup_steps: int = 1000,
                  total_steps: int = 100_000, min_lr: float = 1e-5,
                  init_lr: float = 1e-6):
    """The paper's schedule: ramped linear warm-up from init_lr to base_lr
    over the first epoch, cosine anneal to min_lr afterwards."""
    step = jnp.asarray(step, jnp.float32)
    warm = init_lr + (base_lr - init_lr) * jnp.minimum(
        step / jnp.maximum(warmup_steps, 1), 1.0)
    t = jnp.clip((step - warmup_steps) /
                 jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = min_lr + 0.5 * (base_lr - min_lr) * (1.0 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup_steps, warm, cos)


def constant(step, *, base_lr: float = 1e-4):
    del step
    return jnp.float32(base_lr)
