"""Adam/AdamW from scratch (no optax in this environment).

Optimizer states inherit the parameters' sharding (the paper's zero
redundancy: "each GPU holds 1/n of the total parameters, optimizer states
and input sample").  ``state_dtype`` lets the launcher trade moment
precision for memory on the very large archs (DESIGN.md: jamba-398b
training fits a single pod only with bf16 moments).

Mixed precision (core/precision, DESIGN.md §10): with
``master_weights=True`` the state carries an fp32 master copy of every
parameter and fp32 moments; the update is computed entirely in fp32 from
the masters and cast down into the (donated) ``param_dtype`` buffers.
Without masters, a bf16 parameter stops moving once ``lr * delta`` drops
below one bf16 ulp of its magnitude -- the masters are what make the
``bf16`` policy converge like fp32 (``precision_bf16`` dist scenario).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    state_dtype: Optional[str] = None    # None -> same as param dtype
    grad_clip: Optional[float] = 1.0     # global-norm clip (paper: 1.0)
    master_weights: bool = False         # fp32 masters + fp32 moments


def init(params, cfg: AdamConfig):
    def zeros_like(p):
        if cfg.master_weights:
            dt = jnp.float32                 # moments ride the masters' f32
        else:
            dt = jnp.dtype(cfg.state_dtype) if cfg.state_dtype else p.dtype
        return jnp.zeros(p.shape, dt)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(zeros_like, params),
        "nu": jax.tree.map(zeros_like, params),
    }
    if cfg.master_weights:
        # fp32 source of truth; ``update`` reads/writes these and only
        # casts down into the param buffers the train step donates.
        # copy=True: an already-f32 leaf (norm scales, blend) must NOT
        # alias the param buffer -- the step donates both trees
        state["master"] = jax.tree.map(
            lambda p: jnp.array(p, jnp.float32, copy=True), params)
    return state


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def update(params, grads, state, lr: jax.Array, cfg: AdamConfig
           ) -> Tuple[Any, Any]:
    """One AdamW step. lr may be a traced scalar (schedule)."""
    if cfg.grad_clip is not None:
        grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    masters = state.get("master")

    def upd(p, g, mu, nu, master):
        gf = g.astype(jnp.float32)
        mu_n = b1 * mu.astype(jnp.float32) + (1 - b1) * gf
        nu_n = b2 * nu.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mu_n / c1
        vhat = nu_n / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # fp32 base: the master when present, else the param itself
        base = master if master is not None else p.astype(jnp.float32)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * base
        p_n = base - lr * delta
        return (p_n.astype(p.dtype), mu_n.astype(mu.dtype),
                nu_n.astype(nu.dtype), p_n if master is not None else None)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    flat_ma = (tdef.flatten_up_to(masters) if masters is not None
               else [None] * len(flat_p))
    out = [upd(p, g, m, n, ma) for p, g, m, n, ma
           in zip(flat_p, flat_g, flat_mu, flat_nu, flat_ma)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_state = {"step": step,
                 "mu": tdef.unflatten([o[1] for o in out]),
                 "nu": tdef.unflatten([o[2] for o in out])}
    if masters is not None:
        new_state["master"] = tdef.unflatten([o[3] for o in out])
    return new_p, new_state
