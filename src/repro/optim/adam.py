"""Adam/AdamW from scratch (no optax in this environment).

Optimizer states inherit the parameters' sharding (the paper's zero
redundancy: "each GPU holds 1/n of the total parameters, optimizer states
and input sample").  ``state_dtype`` lets the launcher trade moment
precision for memory on the very large archs (DESIGN.md: jamba-398b
training fits a single pod only with bf16 moments).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    state_dtype: Optional[str] = None    # None -> same as param dtype
    grad_clip: Optional[float] = 1.0     # global-norm clip (paper: 1.0)


def init(params, cfg: AdamConfig):
    def zeros_like(p):
        dt = jnp.dtype(cfg.state_dtype) if cfg.state_dtype else p.dtype
        return jnp.zeros(p.shape, dt)
    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(zeros_like, params),
        "nu": jax.tree.map(zeros_like, params),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def update(params, grads, state, lr: jax.Array, cfg: AdamConfig
           ) -> Tuple[Any, Any]:
    """One AdamW step. lr may be a traced scalar (schedule)."""
    if cfg.grad_clip is not None:
        grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        gf = g.astype(jnp.float32)
        mu_n = b1 * mu.astype(jnp.float32) + (1 - b1) * gf
        nu_n = b2 * nu.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mu_n / c1
        vhat = nu_n / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_n = p.astype(jnp.float32) - lr * delta
        return (p_n.astype(p.dtype), mu_n.astype(mu.dtype),
                nu_n.astype(nu.dtype))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    return new_p, {"step": step, "mu": new_mu, "nu": new_nu}
