"""Unified telemetry subsystem (DESIGN.md §14): structured spans,
counters/gauges/histograms, analytic MFU / comm-fraction accounting, and
Chrome-trace + JSONL export.

Facade:

    from repro import telemetry
    tr = telemetry.get_tracer()          # process tracer (always valid)
    with tr.span("data_wait", step=i):   # monotonic-clock span
        ...
    tr.counter("pipeline.batches")
    tr.gauge("pipeline.queue_depth", 2)
    tr.observe("serve.latency_s", 0.12)

    model = telemetry.build_cost_model(cfg, n_model=4, n_data=2, batch=8)
    tr.step_record(step=i, dur_s=dt, **model.metrics(dt))

    tr.export_chrome("out.trace.json")   # Perfetto / chrome://tracing
    tr.export_jsonl("out.trace.jsonl")   # launch/trace_report.py input

The tracer side (``spans.py``) never imports jax; the accounting side
(``accounting.py``) reuses the exact-dims FLOPs model from
``launch/analysis.py`` and the ring schedule from ``core/jigsaw.py``.
"""
from repro.telemetry.accounting import (StepCostModel, build_cost_model,
                                        fig7_point, hlo_collective_bytes)
from repro.telemetry.spans import (Span, Tracer, get_tracer,
                                   jsonl_path_for, set_tracer)

__all__ = [
    "Span", "StepCostModel", "Tracer", "build_cost_model", "fig7_point",
    "get_tracer", "hlo_collective_bytes", "jsonl_path_for", "set_tracer",
]
