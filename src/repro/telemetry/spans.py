"""Structured spans, counters, gauges, and histograms (DESIGN.md §14).

The process-wide observability primitive every subsystem reports into:

  * ``Tracer.span(name, **args)`` -- a context manager timing a region on
    the monotonic clock.  Spans nest per thread (a thread-local stack
    tracks depth), land in a bounded ring buffer, and export as Chrome
    trace-event "X" (complete) events -- one track per (pid, tid), so a
    Perfetto load shows ckpt-write and ring-hop spans nested under their
    steps, with background threads (prefetch producer, async ckpt
    writer) on their own tracks.
  * ``counter`` / ``add_counters`` -- monotonic accumulators.  The
    ``add_counters`` form applies a whole dict under ONE lock
    acquisition -- the input pipeline uses it to publish a batch's worth
    of I/O accounting atomically from its producer thread (the fix for
    the racy read-modify-write ``PipelineStats`` used to do).
  * ``gauge`` -- last-value instruments (prefetch queue depth); gauge
    updates also record Chrome "C" counter events so the value is a
    plotted track in Perfetto.
  * ``observe`` -- histogram samples with ``percentile``/``hist_summary``
    readouts (the serving engine's admission-to-delivery latencies).
  * ``step_record`` -- one structured dict per training step (the JSONL
    rows ``launch/trace_report.py`` renders; ``accounting.py`` computes
    their mfu / comm_fraction / achieved_tflops fields).

Everything is guarded by one lock per tracer and costs O(µs) per call;
a disabled tracer (``enabled=False``) skips event recording but keeps
counters/gauges live, so subsystems can always report through it.
``benchmarks/telemetry_overhead.py`` holds the <2 % overhead budget.

Zero dependencies beyond the standard library; never imports jax.
"""
from __future__ import annotations

import collections
import io
import json
import os
import threading
import time
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple


def _monotonic_ns() -> int:
    return time.perf_counter_ns()


class Span:
    """One timed region.  Returned by ``Tracer.span`` -- ``dur_s`` is
    readable after the ``with`` block exits (the engine feeds its
    data-wait durations into the step records this way)."""

    __slots__ = ("name", "args", "t0_ns", "dur_ns", "tid", "_tracer")

    def __init__(self, tracer: "Tracer", name: str, args: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.args = args
        self.t0_ns = 0
        self.dur_ns = 0
        self.tid = 0

    @property
    def dur_s(self) -> float:
        return self.dur_ns / 1e9

    def __enter__(self) -> "Span":
        self.t0_ns = _monotonic_ns()
        self._tracer._push(self)
        return self

    def __exit__(self, *exc) -> None:
        self.dur_ns = _monotonic_ns() - self.t0_ns
        self._tracer._pop(self)


class _NullSpan:
    """Shared no-op span for disabled tracers (one instance, no
    allocation on the hot path)."""

    __slots__ = ()
    name = ""
    dur_ns = 0
    dur_s = 0.0

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL_SPAN = _NullSpan()

# span stacks are per (tracer, thread): the tracer keyes the thread-local
# by its own id so two tracers in one process never share a stack
_TLS = threading.local()


class Tracer:
    """Thread-safe span/counter/gauge/histogram recorder with Chrome
    trace-event and JSONL export.

    Parameters
    ----------
    enabled : record span/instant/gauge events into the ring buffer.
        Counters, gauges and histograms stay live either way.
    ring : maximum buffered events (a per-process ring: the newest
        ``ring`` events win -- a multi-day run cannot OOM the host).
    max_hist : per-histogram sample cap (newest samples win).
    """

    def __init__(self, *, enabled: bool = True, ring: int = 200_000,
                 max_hist: int = 100_000):
        self.enabled = enabled
        self.lock = threading.Lock()
        self.pid = os.getpid()
        self.t0_ns = _monotonic_ns()
        self._events: collections.deque = collections.deque(maxlen=ring)
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, collections.deque] = {}
        self._steps: List[Dict[str, Any]] = []
        self._meta: Dict[str, Any] = {}
        self._max_hist = max_hist
        self._thread_names: Dict[int, str] = {}

    # -- spans ----------------------------------------------------------
    def span(self, name: str, **args):
        """Context manager timing a region; nests per thread."""
        if not self.enabled:
            return _NULL_SPAN
        return Span(self, name, args)

    def _stack(self) -> List[Span]:
        stacks = getattr(_TLS, "stacks", None)
        if stacks is None:
            stacks = _TLS.stacks = {}
        st = stacks.get(id(self))
        if st is None:
            st = stacks[id(self)] = []
        return st

    def _push(self, span: Span) -> None:
        st = self._stack()
        span.tid = threading.get_ident()
        st.append(span)

    def _pop(self, span: Span) -> None:
        st = self._stack()
        if st and st[-1] is span:
            st.pop()
        depth = len(st)
        with self.lock:
            self._events.append(
                ("X", span.name, span.t0_ns - self.t0_ns, span.dur_ns,
                 span.tid, depth, span.args or None))
            tn = self._thread_names
            if span.tid not in tn:
                t = threading.current_thread()
                tn[span.tid] = t.name

    def current_span(self) -> Optional[Span]:
        st = self._stack()
        return st[-1] if st else None

    # -- instants / counters / gauges / histograms ----------------------
    def event(self, name: str, **args) -> None:
        """Instant event (Chrome "i" phase) -- restarts, signals,
        final-save markers."""
        if not self.enabled:
            return
        tid = threading.get_ident()
        with self.lock:
            self._events.append(
                ("i", name, _monotonic_ns() - self.t0_ns, 0, tid, 0,
                 args or None))

    def counter(self, name: str, inc: float = 1.0) -> float:
        """Add ``inc`` to a monotonic counter; returns the new total."""
        with self.lock:
            v = self._counters.get(name, 0.0) + inc
            self._counters[name] = v
            return v

    def add_counters(self, updates: Mapping[str, float]) -> None:
        """Apply many counter increments under ONE lock acquisition --
        the batch form producer threads use."""
        with self.lock:
            self.add_counters_locked(updates)

    def add_counters_locked(self, updates: Mapping[str, float]) -> None:
        """Counter increments for callers already inside ``with
        tracer.lock`` -- lets a subsystem update its own state AND its
        counters atomically under the one tracer lock (the input
        pipeline's per-batch I/O accounting)."""
        for name, inc in updates.items():
            self._counters[name] = self._counters.get(name, 0.0) + inc

    def gauge(self, name: str, value: float) -> None:
        """Set an instantaneous value; recorded as a Chrome "C" counter
        track when tracing is enabled."""
        tid = threading.get_ident()
        with self.lock:
            self._gauges[name] = value
            if self.enabled:
                self._events.append(
                    ("C", name, _monotonic_ns() - self.t0_ns, 0, tid, 0,
                     {"value": value}))

    def observe(self, name: str, value: float) -> None:
        """Record one histogram sample."""
        with self.lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = collections.deque(
                    maxlen=self._max_hist)
            h.append(value)

    # -- readouts -------------------------------------------------------
    def counters(self) -> Dict[str, float]:
        with self.lock:
            return dict(self._counters)

    def gauges(self) -> Dict[str, float]:
        with self.lock:
            return dict(self._gauges)

    def percentile(self, name: str, p: float) -> float:
        """p in [0, 1]; nan when the histogram is empty."""
        with self.lock:
            h = self._hists.get(name)
            vals = sorted(h) if h else []
        if not vals:
            return float("nan")
        return vals[min(len(vals) - 1, int(p * len(vals)))]

    def hist_summary(self, name: str) -> Dict[str, float]:
        with self.lock:
            h = self._hists.get(name)
            vals = sorted(h) if h else []
        if not vals:
            return {"count": 0}
        pick = lambda p: vals[min(len(vals) - 1, int(p * len(vals)))]
        return {"count": len(vals), "p50": pick(0.50), "p95": pick(0.95),
                "p99": pick(0.99), "min": vals[0], "max": vals[-1],
                "mean": sum(vals) / len(vals)}

    def hist_names(self) -> List[str]:
        with self.lock:
            return sorted(self._hists)

    # -- structured step records ----------------------------------------
    def set_meta(self, **fields) -> None:
        """Run-level constants stamped into the JSONL header record
        (cost-model terms, mesh shape, policy -- what ``trace_report``
        needs to recompute every derived field)."""
        with self.lock:
            self._meta.update(fields)

    def step_record(self, **fields) -> Dict[str, Any]:
        """Append one per-step record (the JSONL rows)."""
        with self.lock:
            self._steps.append(fields)
        return fields

    def step_records(self) -> List[Dict[str, Any]]:
        with self.lock:
            return list(self._steps)

    def span_summary(self) -> Dict[str, Dict[str, float]]:
        """Aggregate buffered spans by name: count / total_s / mean_s."""
        with self.lock:
            events = list(self._events)
        out: Dict[str, Dict[str, float]] = {}
        for ev in events:
            if ev[0] != "X":
                continue
            agg = out.setdefault(ev[1], {"count": 0, "total_s": 0.0})
            agg["count"] += 1
            agg["total_s"] += ev[3] / 1e9
        for agg in out.values():
            agg["mean_s"] = agg["total_s"] / max(agg["count"], 1)
        return out

    # -- exporters ------------------------------------------------------
    def chrome_events(self) -> List[Dict[str, Any]]:
        """The buffered events in Chrome trace-event dict form (ts/dur
        in microseconds, one (pid, tid) track per thread)."""
        with self.lock:
            events = list(self._events)
            names = dict(self._thread_names)
        out: List[Dict[str, Any]] = []
        out.append({"name": "process_name", "ph": "M", "pid": self.pid,
                    "tid": 0, "args": {"name": f"repro:{self.pid}"}})
        for tid, tname in sorted(names.items()):
            out.append({"name": "thread_name", "ph": "M", "pid": self.pid,
                        "tid": tid, "args": {"name": tname}})
        for ph, name, ts_ns, dur_ns, tid, _depth, args in events:
            ev: Dict[str, Any] = {"name": name, "ph": ph,
                                  "ts": ts_ns / 1e3, "pid": self.pid,
                                  "tid": tid}
            if ph == "X":
                ev["dur"] = dur_ns / 1e3
            if ph == "i":
                ev["s"] = "t"          # thread-scoped instant
            if args:
                ev["args"] = dict(args)
            out.append(ev)
        return out

    def export_chrome(self, path: str) -> None:
        """Write the Chrome trace-event JSON (open in Perfetto /
        chrome://tracing).  Atomic: tmp + rename, so a trace file is
        never torn by a preemption mid-export."""
        doc = {"traceEvents": self.chrome_events(),
               "displayTimeUnit": "ms"}
        tmp = f"{path}.tmp.{self.pid}"
        with open(tmp, "w") as f:
            json.dump(doc, f)
        os.replace(tmp, path)

    def jsonl_records(self) -> List[Dict[str, Any]]:
        """All structured records: meta header, per-step rows, then
        span/counter/gauge/histogram summaries."""
        with self.lock:
            meta = dict(self._meta)
            steps = list(self._steps)
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hist_names = sorted(self._hists)
        recs: List[Dict[str, Any]] = []
        # discriminator last so a meta field named "kind" cannot mask it
        recs.append({**meta, "kind": "meta"})
        for s in steps:
            recs.append({"kind": "step", **s})
        recs.append({"kind": "spans", "spans": self.span_summary()})
        recs.append({"kind": "counters", "counters": counters})
        recs.append({"kind": "gauges", "gauges": gauges})
        for name in hist_names:
            recs.append({"kind": "histogram", "name": name,
                         **self.hist_summary(name)})
        return recs

    def export_jsonl(self, path: str) -> None:
        """Write one JSON object per line (atomic tmp + rename)."""
        buf = io.StringIO()
        for rec in self.jsonl_records():
            buf.write(json.dumps(rec) + "\n")
        tmp = f"{path}.tmp.{self.pid}"
        with open(tmp, "w") as f:
            f.write(buf.getvalue())
        os.replace(tmp, path)


# ---------------------------------------------------------------------------
# Process-wide default tracer
# ---------------------------------------------------------------------------

# Subsystems report through ``get_tracer()``; an engine that wants export
# installs its own via ``set_tracer``.  The default is a disabled tracer:
# counters/gauges stay live (the pipeline's stats lock rides on it even
# in untraced unit tests) but no events are buffered.
_DEFAULT = Tracer(enabled=False)
_CURRENT: Tracer = _DEFAULT


def get_tracer() -> Tracer:
    return _CURRENT


def set_tracer(tracer: Optional[Tracer]) -> Tracer:
    """Install ``tracer`` as the process tracer (None restores the
    disabled default); returns the previous one."""
    global _CURRENT
    prev = _CURRENT
    _CURRENT = tracer if tracer is not None else _DEFAULT
    return prev


def jsonl_path_for(trace_path: str) -> str:
    """Sibling JSONL path for a Chrome trace path:
    ``out.trace.json`` -> ``out.trace.jsonl``."""
    return (trace_path[:-5] if trace_path.endswith(".json")
            else trace_path) + ".jsonl"
