"""Analytic FLOP / wire-byte accounting behind every step record
(DESIGN.md §14).

The paper's headline numbers -- achieved PFLOPs, percent-of-peak,
communication share -- are *derived* quantities: a wall-clock step time
divided into an analytic cost model.  This module builds that model once
per (ModelConfig, Jigsaw scheme, mesh shape) and turns each measured
step duration into

  ``mfu``               achieved FLOP/s per device / peak FLOP/s,
  ``achieved_tflops``   achieved TFLOP/s per device,
  ``comm_fraction``     modeled collective seconds / measured step
                        seconds (the share of the step the Jigsaw wire
                        traffic accounts for at ICI bandwidth),

plus the per-hop wire bytes of the explicit ring schedule
(``core.jigsaw.comm_schedule_jigsaw_1d`` -- the same schedule the fused
kernel enforces).  The FLOPs side reuses ``launch/analysis.py``'s exact
matmul-dims model; the roofline terms are the same formulas as
``benchmarks/fig7_roofline.py`` (``fig7_point`` below reproduces that
benchmark's rows bit-for-bit, pinned by tests/test_telemetry.py).

``hlo_collective_bytes`` cross-checks the analytic wire model against a
compiled step's actual HLO collectives (``launch/analysis.py`` parse).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional

import numpy as np

from repro.core.jigsaw import (comm_schedule_jigsaw_1d,
                               comm_volume_jigsaw_1d,
                               comm_volume_jigsaw_2d)
from repro.launch import analysis as A

# fig7's I/O model constants (paper §5: one 0.25-deg f32 sample over a
# shared Lustre-like host stream)
DISK_BW = 2e9
SAMPLE_BYTES = 4 * 721 * 1440 * 69


def _wire_dtype_bytes(cfg) -> int:
    """Bytes per element on the Jigsaw wire: the policy's compute dtype
    (what the ring ships -- DESIGN.md §10), param dtype otherwise."""
    from repro.core import precision
    pol = precision.policy_of(cfg)
    dt = pol.compute_dtype if pol.name != "legacy" else None
    dt = dt or getattr(cfg, "param_dtype", None) or "float32"
    return np.dtype(dt).itemsize


def _tokens_per_sample(cfg) -> int:
    if cfg.family == "mixer":
        return (cfg.wm_lat // cfg.wm_patch) * (cfg.wm_lon // cfg.wm_patch)
    return 0


@dataclasses.dataclass(frozen=True)
class StepCostModel:
    """Analytic per-step costs for one (config, scheme, mesh) triple.

    ``flops_per_step`` / ``comm_bytes_per_device`` are for ONE rollout
    step (rollout=1); ``metrics`` scales both by the step's actual
    rollout length."""
    arch: str
    scheme: str
    impl: str
    n_model: int
    n_data: int
    batch: int
    flops_per_step: float          # global fwd+bwd(+remat) FLOPs
    comm_bytes_per_device: float   # jigsaw collective bytes, per device
    hops: int                      # ring hops per jigsaw'd linear fwd
    bytes_per_hop: float           # wire bytes per hop per device
    wire_dtype_bytes: int
    approx_comm: bool              # True = non-mixer fallback estimate
    peak_flops: float = A.PEAK_FLOPS_BF16
    ici_bw: float = A.ICI_BW

    @property
    def n_devices(self) -> int:
        return max(self.n_model * self.n_data, 1)

    @property
    def t_compute_s(self) -> float:
        """Compute roofline term: per-device FLOPs at peak."""
        return self.flops_per_step / self.n_devices / self.peak_flops

    @property
    def t_collective_s(self) -> float:
        """Collective roofline term: per-device wire bytes at ICI BW."""
        return self.comm_bytes_per_device / self.ici_bw

    def metrics(self, step_time_s: float,
                rollout: int = 1) -> Dict[str, float]:
        """The derived fields of one step record, from a measured wall
        duration.  All finite for any step_time_s > 0."""
        if step_time_s <= 0:
            return {"mfu": 0.0, "achieved_tflops": 0.0,
                    "comm_fraction": 0.0}
        r = max(int(rollout), 1)
        achieved = (r * self.flops_per_step / self.n_devices
                    / step_time_s)
        return {
            "mfu": achieved / self.peak_flops,
            "achieved_tflops": achieved / 1e12,
            "comm_fraction": min(1.0, r * self.t_collective_s
                                 / step_time_s),
        }

    def as_meta(self) -> Dict[str, Any]:
        """JSON-serializable constants for the trace JSONL header --
        enough for ``trace_report`` to recompute every derived field."""
        d = dataclasses.asdict(self)
        d["t_compute_s"] = self.t_compute_s
        d["t_collective_s"] = self.t_collective_s
        d["n_devices"] = self.n_devices
        return d


def build_cost_model(cfg, *, n_model: int = 1, n_data: int = 1,
                     batch: int = 1, seq_len: int = 128,
                     peak: float = A.PEAK_FLOPS_BF16,
                     ici: float = A.ICI_BW) -> StepCostModel:
    """Cost model for one training step of ``cfg`` on an
    (n_model x n_data) mesh with global batch ``batch``.

    FLOPs: ``launch/analysis.flops_step(kind="train")`` (fwd + bwd, remat
    re-forward when configured) -- exact matmul dims.

    Wire bytes: the Jigsaw collective volume of every sharded linear.
    For the mixer family this is the paper's Fig. 7 model -- fwd+bwd
    (3x) of 2 ring reduce-scatters of ``[tokens, d_ch]`` per layer under
    scheme="1d" (``comm_volume_jigsaw_1d``), Cannon block rotates under
    scheme="2d" (``comm_volume_jigsaw_2d``) -- at the policy's wire
    dtype.  Non-mixer families get a d_model-proportional estimate
    (flagged ``approx_comm``)."""
    n_model = max(int(n_model), 1)
    n_data = max(int(n_data), 1)
    flops = A.flops_step(cfg, "train", batch, seq_len)
    wire = _wire_dtype_bytes(cfg)
    scheme = cfg.scheme if n_model > 1 else "none"
    impl = getattr(cfg, "impl", "ring") or "ring"

    comm = 0.0
    hops, hop_bytes, approx = 0, 0.0, False
    if scheme != "none" and n_model > 1:
        if cfg.family == "mixer":
            tokens = batch * _tokens_per_sample(cfg)
            m = cfg.wm_d_ch
        else:
            tokens = batch * seq_len
            m = cfg.d_model
            approx = True
        q = int(math.isqrt(n_model))
        if scheme == "2d" and q * q == n_model and q > 1:
            vol = comm_volume_jigsaw_2d(tokens, m, q, dtype_bytes=wire)
            comm = 3.0 * vol.bytes_per_device * 2 * cfg.n_layers
            hops = 2 * (q - 1)
            hop_bytes = vol.bytes_per_device / hops
        else:
            p = n_model
            sched = comm_schedule_jigsaw_1d(
                tokens, m, cfg.d_model // p or 1, p,
                dtype_bytes=wire,
                impl=impl if impl in ("ring", "ring_chunked",
                                      "ring_fused") else "ring")
            comm = 3.0 * (comm_volume_jigsaw_1d(tokens, m, p,
                                                dtype_bytes=wire)
                          .bytes_per_device * 2 * cfg.n_layers)
            hops, hop_bytes = sched.hops, sched.bytes_per_hop
    return StepCostModel(
        arch=cfg.arch_id, scheme=scheme, impl=impl,
        n_model=n_model, n_data=n_data, batch=batch,
        flops_per_step=float(flops), comm_bytes_per_device=float(comm),
        hops=hops, bytes_per_hop=float(hop_bytes),
        wire_dtype_bytes=wire, approx_comm=approx,
        peak_flops=peak, ici_bw=ici)


# ---------------------------------------------------------------------------
# fig7 parity + HLO cross-check
# ---------------------------------------------------------------------------

def fig7_point(cfg, way: int, impl: Optional[str] = None
               ) -> Dict[str, float]:
    """One row of the Fig. 7 roofline, exactly as
    ``benchmarks/fig7_roofline.py`` computes it (same formulas, same
    constants) -- the pinned reference for the MFU accounting test.

    Returns t_step_s / tflops_per_dev / peak_frac / regime for a mixer
    config at jigsaw width ``way`` (1, 2 = 1-D ring, 4 = 2-D Cannon);
    ``impl`` in ("ring_chunked", "ring_fused") applies the overlap
    schedule ``t_comp/p + max(t_comp (p-1)/p, t_coll)``."""
    flops = 3 * sum(A.flops_forward(cfg, 1, 0).values())
    t_tokens = _tokens_per_sample(cfg)
    t_io = SAMPLE_BYTES / (way * DISK_BW)
    t_comp = flops / (way * A.PEAK_FLOPS_BF16)
    if way == 1:
        t_coll, p_ring = 0.0, 1
    elif way == 2:
        v = 3 * (comm_volume_jigsaw_1d(t_tokens, cfg.wm_d_ch, way)
                 .bytes_per_device * 2 * cfg.n_layers)
        t_coll, p_ring = v / A.ICI_BW, way
    else:
        v = 3 * (comm_volume_jigsaw_2d(t_tokens, cfg.wm_d_ch, 2)
                 .bytes_per_device * 2 * cfg.n_layers)
        t_coll, p_ring = v / A.ICI_BW, 2
    if impl in ("ring_chunked", "ring_fused") and p_ring > 1:
        t_cc = t_comp / p_ring + max(t_comp * (p_ring - 1) / p_ring,
                                     t_coll)
    else:
        t_cc = t_comp + t_coll
    t_step = max(t_io, t_cc)
    achieved = flops / t_step / way
    return {"t_step_s": t_step, "t_io_s": t_io, "t_comp_s": t_comp,
            "t_coll_s": t_coll,
            "tflops_per_dev": achieved / 1e12,
            "peak_frac": achieved / A.PEAK_FLOPS_BF16,
            "regime": "io" if t_io > t_cc else "compute-comm"}


def hlo_collective_bytes(compiled) -> float:
    """Total collective bytes of a compiled step (per device), from the
    HLO text -- the measured side of the wire-byte cross-check."""
    return A.collective_stats(compiled.as_text()).total_bytes
