"""Precision policy: which dtype each stage of the hot path runs in.

The paper's peak-PFLOP numbers (§6) assume the MXU runs at its bf16 rate
and that the Jigsaw ring hops move half-width partial sums.  A ``Policy``
names the three dtypes that decide both:

  param_dtype    storage dtype of the trainable parameters -- the buffers
                 the train step donates and the checkpoint shards hold;
  compute_dtype  dtype of every GEMM operand AND of every byte that rides
                 a collective (ring/Cannon ``ppermute`` chunks,
                 ``psum_scatter`` inputs).  bf16 halves per-hop ICI bytes
                 relative to fp32 -- asserted on compiled HLO by
                 ``benchmarks/comm_volume.py`` and the ``precision_bf16``
                 dist scenario;
  accum_dtype    dtype partial sums are ACCUMULATED in across ranks/chunks
                 (the ring's adds, Cannon's q-step accumulator).  The MXU
                 itself always accumulates fp32 inside the Pallas kernel
                 (``preferred_element_type`` / f32 VMEM scratch); this
                 knob governs what happens BETWEEN kernel calls.

plus the optimizer split:

  master_weights fp32 master copy of every parameter lives in the Adam
                 state; the update is computed fp32-from-masters and cast
                 down into the (donated) ``param_dtype`` buffers.  Without
                 masters, repeated cast-down of tiny updates stalls
                 training once ``lr * delta`` drops below one bf16 ulp of
                 the weight.
  moment_dtype   Adam mu/nu storage.

Named presets (``get_policy``):

  fp32       everything float32 -- the numerical reference.
  bf16       mixed precision: bf16 params/compute, fp32 ring accumulation,
             fp32 master weights + fp32 moments.  This is the production
             policy: ~2x MXU throughput and ~0.5x collective bytes at
             fp32-equivalent convergence (loss-parity asserted by the
             ``precision_bf16`` scenario).
  bf16_pure  memory-minimal: bf16 everywhere incl. ring accumulation and
             moments, no masters (the "jamba-398b fits a single pod only
             with bf16 moments" regime -- accepts the convergence risk).

``policy_of(cfg)`` resolves a ModelConfig: an explicit ``cfg.precision``
names a preset; otherwise a legacy policy is derived from the config's
``param_dtype``/``compute_dtype`` fields (fp32 accumulation, no masters)
so pre-policy behavior is reproduced exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Union

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Policy:
    name: str = "fp32"
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    accum_dtype: Any = jnp.float32
    master_weights: bool = False
    moment_dtype: Optional[Any] = None   # None -> param dtype

    def replace(self, **kw) -> "Policy":
        return dataclasses.replace(self, **kw)


PRESETS = {
    "fp32": Policy("fp32", jnp.float32, jnp.float32, jnp.float32,
                   master_weights=False, moment_dtype=jnp.float32),
    "bf16": Policy("bf16", jnp.bfloat16, jnp.bfloat16, jnp.float32,
                   master_weights=True, moment_dtype=jnp.float32),
    "bf16_pure": Policy("bf16_pure", jnp.bfloat16, jnp.bfloat16,
                        jnp.bfloat16, master_weights=False,
                        moment_dtype=jnp.bfloat16),
}


def get_policy(p: Union[str, Policy, None]) -> Policy:
    """Resolve a preset name (or pass a Policy through; None -> fp32)."""
    if p is None:
        return PRESETS["fp32"]
    if isinstance(p, Policy):
        return p
    if p not in PRESETS:
        raise ValueError(f"unknown precision preset {p!r} "
                         f"(have {sorted(PRESETS)})")
    return PRESETS[p]


def policy_of(cfg) -> Policy:
    """Policy for a ModelConfig.

    ``cfg.precision`` (set by ``apply_policy`` / the ``--precision``
    flag) names a preset.  When unset (None), derive the legacy policy
    from the config's dtype strings: fp32 accumulation, no master
    weights -- byte-for-byte the pre-policy behavior, so every existing
    config / test is unaffected.
    """
    name = getattr(cfg, "precision", None)
    if name:
        return get_policy(name)
    return Policy(name="legacy",
                  param_dtype=jnp.dtype(cfg.param_dtype),
                  compute_dtype=jnp.dtype(cfg.compute_dtype),
                  accum_dtype=jnp.float32, master_weights=False,
                  moment_dtype=None)


def apply_policy(cfg, p: Union[str, Policy]):
    """Return ``cfg`` with the policy threaded into its dtype fields.

    Models init params from ``cfg.param_dtype`` and the engine derives
    its JigsawConfig/AdamConfig from ``policy_of(cfg)``, so this one
    replace() is the single point where a preset takes effect."""
    pol = get_policy(p)
    return cfg.replace(precision=pol.name,
                       param_dtype=jnp.dtype(pol.param_dtype).name,
                       compute_dtype=jnp.dtype(pol.compute_dtype).name)
