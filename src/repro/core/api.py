"""Composable Jigsaw modules: functional param-init / apply pairs.

Everything in this framework is a pure function over parameter pytrees
(nested dicts of jax.Arrays).  ``JigsawConfig`` selects how each linear
layer completes its distributed contraction:

  scheme="1d", impl in {"ring","ring_chunked","rs","gspmd","allreduce"}
                                                (paper 2-way, n-way)
  scheme="2d"                                   (paper 4-way, Cannon)

``impl="rs"`` (psum_scatter) is the default production path;
``"ring_chunked"`` is the paper's own schedule (one output-chunk GEMM
issued before each hop so send overlaps compute); ``"ring"`` is the
monolithic-GEMM approximation of it; ``"gspmd"`` lets XLA derive the
collectives from sharding constraints alone (beyond-paper comparison).

``kernel`` selects the compute engine of every local GEMM: ``"xla"``
(dot_general) or ``"pallas"`` (the MXU-tiled blocked kernel,
kernels/block_matmul.py -- f32 VMEM accumulation, and where the
contraction is already complete, i.e. the undistributed scheme="none"
path, the bias add and GELU ride the kernel's fused epilogue).  Under a
distributed scheme the epilogue cannot fuse: the partial products are
incomplete until the reduce-scatter / ring finishes, so bias/activation
apply after the collective (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import jigsaw
from repro.core.sharding import RULES_1D, ShardingRules, constrain


@dataclasses.dataclass(frozen=True)
class JigsawConfig:
    rules: ShardingRules = RULES_1D
    scheme: str = "1d"            # "1d" | "2d" | "none"
    impl: str = "rs"              # for scheme="1d"
    accum_dtype: Optional[jnp.dtype] = jnp.float32
    fsdp: bool = False            # weights also sharded over data (huge archs)
    kernel: str = "xla"           # "xla" | "pallas" (local GEMM engine)
    # precision-policy compute dtype (core/precision): every linear casts
    # its operands here before the GEMM/collectives, so bf16 halves both
    # MXU time and per-hop ring bytes.  None = no cast (legacy).
    compute_dtype: Optional[jnp.dtype] = None

    def __post_init__(self):
        # Fail fast on unknown knobs and surface combinations that would
        # otherwise be *silently* ignored (the scheme dispatch only reads
        # ``impl`` under scheme="1d").
        if self.scheme not in ("1d", "2d", "none"):
            raise ValueError(f"JigsawConfig: unknown scheme {self.scheme!r}"
                             " (expected '1d' | '2d' | 'none')")
        if self.impl not in jigsaw.Impl1D:
            raise ValueError(f"JigsawConfig: unknown impl {self.impl!r} "
                             f"(expected one of {jigsaw.Impl1D})")
        if self.kernel not in jigsaw.Kernels:
            raise ValueError(f"JigsawConfig: unknown kernel {self.kernel!r}"
                             f" (expected one of {jigsaw.Kernels})")
        if self.scheme != "1d" and self.impl != "rs":
            warnings.warn(
                f"JigsawConfig: impl={self.impl!r} only applies to "
                f"scheme='1d'; scheme={self.scheme!r} ignores it "
                "(2-D uses Cannon, 'none' is undistributed)",
                stacklevel=3)

    def replace(self, **kw) -> "JigsawConfig":
        return dataclasses.replace(self, **kw)


DEFAULT_JIGSAW = JigsawConfig()
GSPMD_JIGSAW = JigsawConfig(impl="gspmd")


def head_config(cfg: JigsawConfig) -> JigsawConfig:
    """Jigsaw config for the LM head / unembed.

    The explicit reduce-scatter is the paper's scheme for *inner* layers,
    but for the final vocab projection its transpose (an all-gather of the
    full-vocab gradient, ~22 GiB/device at train_4k) is catastrophic.
    With sharding constraints only, the cross-entropy stays element-wise
    over the vocab-sharded logits and the gradient never materializes
    unsharded (EXPERIMENTS.md #Perf, iteration 1)."""
    if cfg.scheme == "1d":
        return cfg.replace(impl="gspmd")
    return cfg


# ---------------------------------------------------------------------------
# Linear
# ---------------------------------------------------------------------------

def linear_init(key: jax.Array, d_in: int, d_out: int, *,
                dtype=jnp.float32, bias: bool = True, scale: float = None):
    """Weights stored [d_out, d_in] (y = x @ w.T + b), LeCun-normal init."""
    scale = (1.0 / math.sqrt(d_in)) if scale is None else scale
    w = jax.random.normal(key, (d_out, d_in), dtype=jnp.float32) * scale
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear_apply(params, x: jax.Array, cfg: JigsawConfig = DEFAULT_JIGSAW,
                 *, domain_dim: int = -2,
                 epilogue: str = "none") -> jax.Array:
    """``y = epilogue(x @ w.T + b)``.

    ``epilogue`` ("none" | "gelu" | "silu") only fuses into the GEMM on
    the undistributed pallas path, where the contraction is complete
    inside the kernel; distributed schemes apply it after the collective.
    """
    w = params["w"]
    b = params.get("b")
    act = None if epilogue == "none" else getattr(jax.nn, epilogue)
    if cfg.scheme == "2d":
        y = jigsaw.jigsaw_linear_2d(x, w, b, rules=cfg.rules,
                                    domain_dim=domain_dim,
                                    accum_dtype=cfg.accum_dtype,
                                    kernel=cfg.kernel,
                                    compute_dtype=cfg.compute_dtype)
        return y if act is None else act(y)
    if cfg.scheme == "1d":
        y = jigsaw.jigsaw_linear(x, w, b, rules=cfg.rules, impl=cfg.impl,
                                 accum_dtype=cfg.accum_dtype,
                                 w_data_sharded=cfg.fsdp,
                                 kernel=cfg.kernel,
                                 compute_dtype=cfg.compute_dtype)
        return y if act is None else act(y)
    # scheme="none": plain local matmul (single-device / inside-shard_map)
    x, w, b = jigsaw._cast_operands(x, w, b, cfg.compute_dtype)
    if cfg.kernel == "pallas":
        # contraction completes in-kernel: bias + activation ride the
        # fused epilogue, the activation never round-trips to HBM.
        from repro.kernels import ops
        return ops.matmul_nd(x, w, b, epilogue=epilogue)
    y = jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=cfg.accum_dtype or x.dtype).astype(x.dtype)
    y = y if b is None else y + b
    return y if act is None else act(y)


# ---------------------------------------------------------------------------
# MLP (two linears + GELU) -- the WeatherMixer building block
# ---------------------------------------------------------------------------

def mlp_init(key: jax.Array, d_in: int, d_hidden: int, d_out: int, *,
             dtype=jnp.float32, bias: bool = True):
    k1, k2 = jax.random.split(key)
    return {"fc1": linear_init(k1, d_in, d_hidden, dtype=dtype, bias=bias),
            "fc2": linear_init(k2, d_hidden, d_out, dtype=dtype, bias=bias)}


def mlp_apply(params, x: jax.Array, cfg: JigsawConfig = DEFAULT_JIGSAW,
              *, activation=jax.nn.gelu, domain_dim: int = -2) -> jax.Array:
    if cfg.kernel == "pallas" and cfg.scheme == "none" \
            and activation is jax.nn.gelu:
        # Fused two-GEMM path (the WeatherMixer mixing MLPs and every
        # gelu-kind encoder/decoder FFN): the first GEMM's bias + GELU
        # run in its VMEM epilogue, the hidden activation feeds the
        # second GEMM without an unfused elementwise pass between.
        from repro.kernels import ops
        x, w1, b1 = jigsaw._cast_operands(
            x, params["fc1"]["w"], params["fc1"].get("b"), cfg.compute_dtype)
        _, w2, b2 = jigsaw._cast_operands(
            x, params["fc2"]["w"], params["fc2"].get("b"), cfg.compute_dtype)
        return ops.mixer_mlp(x, w1, b1, w2, b2)
    h = linear_apply(params["fc1"], x, cfg, domain_dim=domain_dim)
    h = activation(h)
    return linear_apply(params["fc2"], h, cfg, domain_dim=domain_dim)


def param_spec_tree(params, rules: ShardingRules, scheme: str = "1d"):
    """PartitionSpecs for a linear/MLP param subtree (w: jigsaw layout,
    b: sharded along the tp axis to match the output)."""
    from jax.sharding import PartitionSpec as P

    def spec_for(path, leaf):
        name = path[-1]
        if name == "w":
            return rules.weight(leaf.ndim) if scheme != "none" \
                else rules.replicated(leaf.ndim)
        if name == "b":
            return P(rules.tp_axis) if scheme != "none" else P(None)
        return rules.replicated(leaf.ndim)

    def walk(tree, path=()):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        return spec_for(path, tree)

    return walk(params)
