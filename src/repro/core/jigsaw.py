"""Jigsaw distributed matrix-matrix multiplication (the paper's core).

The paper defines Jigsaw as a zero-memory-redundancy distributed matmul in
which BOTH the activations X and the weights W are block-sharded, and the
contraction ``X @ W.T`` is completed by exchanging partial sums between
ranks while each rank computes its local block (communication overlapped
with computation, MPI point-to-point in the paper).

TPU/JAX adaptation (see DESIGN.md §2):

* **1-D Jigsaw** (paper §4.1, "2-way", generalized here to n-way): X is
  sharded along its last (channel) dim, W along its contracting dim.  Each
  rank computes the full partial product ``X_r @ W_r.T`` and the partial
  sums are combined with a *ring reduce-scatter*, leaving the output
  sharded along its last dim -- the same layout as the input, so layers
  compose without any re-sharding and no weight is ever allgathered.

  Four interchangeable implementations:
    - ``ring``  : explicit ppermute ring of partial-sum chunks.  The whole
                  local partial product is computed up-front with ONE GEMM
                  and the ring then only moves chunks of it -- an
                  approximation of the paper's schedule with zero
                  guaranteed overlap (the compute is finished before the
                  first hop is issued).
    - ``ring_chunked`` : the paper's actual algorithm.  The local weight
                  block is split into p output-chunks and chunk j's GEMM
                  is issued immediately before hop j's ppermute, so every
                  hop's send can overlap the NEXT chunk's compute ("each
                  hop's send overlaps the next chunk's compute", §4).
                  GEMMs and hops are still separate HLOs: overlap is
                  XLA-best-effort.
    - ``ring_fused`` : the same schedule as ONE pallas_call per ring
                  (kernels/fused_ring.py): remote-DMA hops issued from
                  inside the kernel while the next chunk's MXU GEMM runs
                  -- overlap guaranteed by construction, not by the
                  scheduler.  Deterministic chunk-granular fallback off
                  TPU; bit-identical to ``ring`` (fwd + grads) under
                  every precision policy.
    - ``rs``    : ``jax.lax.psum_scatter`` -- XLA's native reduce-scatter,
                  which lowers to the same ring on the ICI torus but lets
                  the compiler schedule the overlap.
    - ``gspmd`` : no explicit collectives; sharding constraints only.  XLA
                  GSPMD derives the schedule.  (beyond-paper comparison)

  The local GEMMs route through either XLA's dot_general or the MXU-tiled
  Pallas kernel (``kernel="pallas"``, kernels/block_matmul.py): f32 VMEM
  accumulation, differentiable via a custom VJP whose backward GEMMs run
  the same kernel.

* **2-D Jigsaw** (paper §4.2, "4-way", generalized here to p x q): X is
  sharded over (token/longitude x channel) and W over (out x in) blocks;
  the contraction is Cannon's algorithm (the paper cites Cannon/SUMMA as
  the underlying idea) via ppermute skew + rotate steps.

Both are differentiable through JAX AD: the transpose of a ring
reduce-scatter is a ring allgather, which reproduces the paper's
"backward pass is the transposed multiplication, performed analogously".
"""
from __future__ import annotations

import dataclasses
import string
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import get_abstract_mesh, shard_map
from repro.core.sharding import ShardingRules, constrain

Impl1D = ("ring", "ring_chunked", "ring_fused", "rs", "gspmd", "allreduce")
Kernels = ("xla", "pallas")


# --------------------------------------------------------------------------
# Ring collectives (paper-faithful explicit schedules)
# --------------------------------------------------------------------------

def ring_reduce_scatter(x: jax.Array, axis_name: str, axis_size: int,
                        scatter_dim: int = -1,
                        accum_dtype: Optional[jnp.dtype] = None) -> jax.Array:
    """Ring reduce-scatter of ``x`` along ``axis_name``.

    Every rank holds a full partial sum ``x``; afterwards rank ``r`` holds
    chunk ``r`` of ``sum_over_ranks(x)`` along ``scatter_dim``.  This is the
    n-way generalization of the paper's 2-way partial-sum exchange: at each
    of the p-1 steps a rank forwards its accumulator to the next neighbour
    while (in the lowered schedule) computing/adding the next local chunk.

    Mixed precision (core/precision): the WIRE format is ``x.dtype`` --
    every ``ppermute`` hop ships x.dtype bytes (bf16 halves per-hop ICI
    volume vs fp32) -- while the adds between hops run in ``accum_dtype``
    (rounding once per hop at the cast-down for the wire instead of
    accumulating error in bf16).  ``accum_dtype=None`` or == x.dtype is
    bit-identical to the unparameterized schedule.
    """
    p = axis_size
    if p == 1:
        return x
    dim = scatter_dim % x.ndim
    if x.shape[dim] % p != 0:
        raise ValueError(
            f"ring_reduce_scatter: dim {dim} of {x.shape} not divisible by {p}")
    chunk = x.shape[dim] // p
    idx = jax.lax.axis_index(axis_name)
    acc_dt = accum_dtype or x.dtype

    def get(j):
        c = jax.lax.dynamic_slice_in_dim(x, j * chunk, chunk, axis=dim)
        return c.astype(acc_dt)

    perm = [(i, (i + 1) % p) for i in range(p)]
    # Initialize with the chunk destined for our successor ring-walk; after
    # p-1 shift+add steps the accumulator is exactly chunk ``idx`` of the
    # global sum (see tests/test_jigsaw.py for the algebra check).
    acc = get((idx + p - 1) % p)
    for s in range(p - 1):
        acc = jax.lax.ppermute(acc.astype(x.dtype), axis_name, perm)
        acc = acc.astype(acc_dt) + get((idx - 2 - s) % p)
    return acc.astype(x.dtype)


def ring_all_gather(x: jax.Array, axis_name: str, axis_size: int,
                    gather_dim: int = -1) -> jax.Array:
    """Ring allgather (transpose of ring_reduce_scatter); used for
    comparison baselines, not by Jigsaw itself (zero redundancy!)."""
    p = axis_size
    if p == 1:
        return x
    dim = gather_dim % x.ndim
    idx = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % p) for i in range(p)]
    pieces = [x]
    cur = x
    for _ in range(p - 1):
        cur = jax.lax.ppermute(cur, axis_name, perm)
        pieces.append(cur)
    # piece j in ``pieces`` originated at rank (idx - j) % p; reorder into
    # rank order before concatenating along ``dim``.
    stacked = jnp.stack(pieces, axis=0)           # [p, ..., chunk]
    order = (idx - jnp.arange(p, dtype=jnp.int32)) % p
    inv = jnp.zeros((p,), jnp.int32).at[order].set(
        jnp.arange(p, dtype=jnp.int32))
    stacked = jnp.take(stacked, inv, axis=0)
    return jnp.concatenate([stacked[j] for j in range(p)], axis=dim)


# --------------------------------------------------------------------------
# 1-D Jigsaw (n-way generalization of the paper's 2-way scheme)
# --------------------------------------------------------------------------

def _local_matmul(x: jax.Array, w: jax.Array,
                  accum_dtype: Optional[jnp.dtype],
                  kernel: str = "xla") -> jax.Array:
    """x: [..., d_local], w: [m, d_local] -> [..., m] (partial sum).

    ``kernel="pallas"`` routes through the MXU-tiled blocked GEMM
    (kernels/ops.matmul: f32 VMEM accumulation, custom VJP); the result
    comes back in x.dtype, which is what every caller reduces in anyway.
    """
    if kernel == "pallas":
        from repro.kernels import ops
        return ops.matmul_nd(x, w, None, epilogue="none")
    out = jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=accum_dtype or x.dtype)
    return out


def ring_matmul_chunked(x: jax.Array, w: jax.Array, *, axis_name: str,
                        axis_size: int,
                        accum_dtype: Optional[jnp.dtype] = jnp.float32,
                        kernel: str = "xla") -> jax.Array:
    """Chunk-granular fused compute/communication ring (paper §4).

    Instead of one local GEMM followed by a reduce-scatter of its output
    (``ring``/``rs``), the local weight block w [m, d/p] is split into p
    output-chunks of m/p rows and chunk j's GEMM is computed immediately
    before hop j's ppermute.  The schedule visits exactly the chunk order
    of ``ring_reduce_scatter``, so the result is bit-identical; the
    difference is that each hop's send is issued while the *next* chunk's
    GEMM is still pending, giving XLA (and the ICI DMA engines) a
    dependency graph in which communication overlaps computation -- the
    paper's "each hop's send overlaps the next chunk's compute".

    Wire format is ``x.dtype`` (bf16 compute halves per-hop bytes); the
    hop-to-hop adds run in ``accum_dtype`` -- the same cast points as
    ``ring_reduce_scatter``, so ring_chunked == ring stays bit-identical
    under every precision policy.
    """
    p = axis_size
    if p == 1:
        return _local_matmul(x, w, accum_dtype, kernel).astype(x.dtype)
    m = w.shape[0]
    if m % p != 0:
        raise ValueError(
            f"ring_matmul_chunked: out dim {m} not divisible by {p}")
    chunk = m // p
    idx = jax.lax.axis_index(axis_name)
    acc_dt = accum_dtype or x.dtype

    def chunk_mm(j):
        # GEMM of one output-chunk: x @ w[j*chunk:(j+1)*chunk].T -- cast
        # to the compute (wire) dtype first, exactly like the monolithic
        # ring's partial_sum, then up to the accumulation dtype.
        wj = jax.lax.dynamic_slice_in_dim(w, j * chunk, chunk, axis=0)
        y = _local_matmul(x, wj, accum_dtype, kernel).astype(x.dtype)
        return y.astype(acc_dt)

    perm = [(i, (i + 1) % p) for i in range(p)]
    # Same walk as ring_reduce_scatter: start with the chunk destined for
    # our successor; after p-1 hop+compute steps the accumulator is chunk
    # ``idx`` of the global sum.
    acc = chunk_mm((idx + p - 1) % p)
    for s in range(p - 1):
        acc = jax.lax.ppermute(acc.astype(x.dtype), axis_name, perm)
        acc = acc.astype(acc_dt) + chunk_mm((idx - 2 - s) % p)
    return acc.astype(x.dtype)


def jigsaw_matmul_1d(x: jax.Array, w: jax.Array, *, axis_name: str,
                     axis_size: int, impl: str = "rs",
                     accum_dtype: Optional[jnp.dtype] = jnp.float32,
                     kernel: str = "xla",
                     mesh_axes: Optional[Tuple[str, ...]] = None
                     ) -> jax.Array:
    """Manual (inside-shard_map) 1-D Jigsaw matmul.

    x: local [..., d/p] block; w: local [m, d/p] block.
    Returns the local [..., m/p] block of ``X @ W.T``.
    ``mesh_axes`` (mesh axis names, mesh order) is only consumed by the
    ``ring_fused`` TPU kernel to address its ring neighbours.
    """
    if impl == "ring_fused":
        # One pallas_call per ring (kernels/fused_ring.py): the fused-hop
        # schedule with in-kernel RDMA on TPU, chunk-granular fallback
        # elsewhere.  Lazy import keeps core -> kernels one-way and cheap.
        from repro.kernels import fused_ring
        return fused_ring.fused_ring_matmul(
            x, w, axis_name=axis_name, axis_size=axis_size,
            accum_dtype=accum_dtype, kernel=kernel,
            mesh_axes=mesh_axes).astype(x.dtype)
    if impl == "ring_chunked":
        return ring_matmul_chunked(
            x, w, axis_name=axis_name, axis_size=axis_size,
            accum_dtype=accum_dtype, kernel=kernel).astype(x.dtype)
    partial_sum = _local_matmul(x, w, accum_dtype, kernel)
    # reduce in the compute dtype: halves collective bytes (and the
    # transposed allgather in backward) at negligible accuracy cost
    partial_sum = partial_sum.astype(x.dtype)
    if impl == "ring":
        out = ring_reduce_scatter(partial_sum, axis_name, axis_size,
                                  accum_dtype=accum_dtype)
    elif impl == "rs":
        out = jax.lax.psum_scatter(partial_sum, axis_name,
                                   scatter_dimension=partial_sum.ndim - 1,
                                   tiled=True)
    elif impl == "allreduce":
        # Megatron-style completion (for comparison): full allreduce, then
        # slice our chunk.  2x the bytes of reduce-scatter + result is
        # materialized fully on every rank before slicing.
        full = jax.lax.psum(partial_sum, axis_name)
        p = axis_size
        chunk = full.shape[-1] // p
        idx = jax.lax.axis_index(axis_name)
        out = jax.lax.dynamic_slice_in_dim(full, idx * chunk, chunk, axis=-1)
    else:
        raise ValueError(f"unknown 1-D jigsaw impl {impl!r}")
    return out.astype(x.dtype)


def _present_batch_axes(mesh, rules: ShardingRules):
    return tuple(a for a in rules.batch_axes if a in mesh.shape)


def _cast_operands(x, w, b, compute_dtype):
    """Cast a linear's operands to the policy compute dtype (the block-
    boundary cast: params stored in param_dtype, GEMMs + collectives run
    in compute_dtype).  No-ops when dtypes already match."""
    if compute_dtype is None:
        return x, w, b
    cd = jnp.dtype(compute_dtype)
    return (x.astype(cd), w.astype(cd),
            None if b is None else b.astype(cd))


def _gspmd_pallas_dot(x: jax.Array, w: jax.Array, mesh,
                      rules: ShardingRules) -> jax.Array:
    """Dense ``x @ w.T`` on the Pallas GEMM under GSPMD sharding.

    Manual only over the batch axes (the model axes stay with GSPMD): at
    the region boundary GSPMD allgathers x's channel shards / w's blocks,
    the local GEMM runs ops.matmul_nd, and the caller's ``constrain``
    re-shards the output.  Used by the gspmd / p==1 / uneven fallback so
    ``kernel="pallas"`` is honoured there too.
    """
    from repro.kernels import ops
    batch_axes = _present_batch_axes(mesh, rules)
    dp = 1
    for a in batch_axes:
        dp *= mesh.shape[a]
    shard_batch = (x.ndim >= 2 and dp > 1 and x.shape[0] % dp == 0)
    if not shard_batch:
        # no data axes in play (single device / replicated batch): the
        # local GEMM IS the global GEMM.
        return ops.matmul_nd(x, w, None, epilogue="none")
    xdims: list = [None] * x.ndim
    xdims[0] = batch_axes
    xspec = P(*xdims)

    def fn(xl, wl):
        return ops.matmul_nd(xl, wl, None, epilogue="none")

    return shard_map(fn, mesh=mesh, in_specs=(xspec, P(None, None)),
                     out_specs=xspec, axis_names=set(batch_axes),
                     check_vma=False)(x, w)


def jigsaw_linear(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
                  *, rules: ShardingRules, mesh=None, impl: str = "rs",
                  accum_dtype: Optional[jnp.dtype] = jnp.float32,
                  w_data_sharded: bool = False,
                  kernel: str = "xla",
                  compute_dtype: Optional[jnp.dtype] = None) -> jax.Array:
    """Public 1-D Jigsaw linear: ``y = x @ w.T (+ b)``.

    Layouts (global view):
      x: [B, ..., d]  batch on the data axes, d on the tp axis -- zero
                      activation redundancy (domain parallelism),
      w: [m, d]       d (contracting) on the tp axis -- zero weight
                      redundancy; optionally m over the data axis too
                      (``w_data_sharded``: the FSDP-hybrid for >16-GB/chip
                      archs -- w is ring-allgathered over data inside),
      y: [B, ..., m]  same layout as x: layers compose with no resharding.

    The shard_map is *fully manual* over every mesh axis it touches --
    partially-auto shard_map replicates inputs over unmentioned axes,
    which would allgather the global batch on every linear.
    ``impl='gspmd'`` skips the explicit collectives entirely (sharding
    constraints only; beyond-paper comparison).
    """
    x, w, b = _cast_operands(x, w, b, compute_dtype)
    tp = rules.tp_axis
    if mesh is None:
        mesh = get_abstract_mesh()
    p = mesh.shape[tp] if tp in mesh.shape else 1

    # Uneven shapes cannot ride the explicit shard_map collectives (even
    # block division required); GSPMD pads such cases transparently.
    uneven = (x.shape[-1] % p != 0) or (w.shape[0] % p != 0) \
        or (w.shape[1] % p != 0)
    if impl == "gspmd" or p == 1 or uneven:
        if kernel == "pallas":
            # A pallas_call is an opaque custom call GSPMD cannot
            # partition THROUGH, so the dense dot rides a shard_map that
            # is manual over the batch axes only: GSPMD places the
            # gather/reshard collectives at the region boundary and the
            # local GEMM itself runs the MXU-tiled kernel -- the knob is
            # honoured instead of silently ignored.
            y = _gspmd_pallas_dot(x, w, mesh, rules)
        else:
            y = jax.lax.dot_general(
                x, w, (((x.ndim - 1,), (1,)), ((), ())),
                preferred_element_type=accum_dtype or x.dtype
            ).astype(x.dtype)
        y = constrain(y, rules.act(y.ndim))
        if b is not None:
            y = y + b
        return y

    batch_axes = _present_batch_axes(mesh, rules)
    dp = 1
    for a in batch_axes:
        dp *= mesh.shape[a]
    shard_batch = (x.ndim >= 2 and dp > 1 and x.shape[0] % dp == 0)
    # data axis carrying FSDP weight shards (last batch axis by convention)
    fsdp_axis = batch_axes[-1] if (w_data_sharded and batch_axes) else None
    fsdp_ok = (fsdp_axis is not None
               and w.shape[0] % mesh.shape[fsdp_axis] == 0)

    # Always fully-manual over the batch axes too: partially-auto
    # shard_map both replicates inputs over unmentioned axes AND trips an
    # XLA SPMD crash ("Invalid binary instruction opcode copy") at
    # 512 devices.  Non-divisible batch (e.g. long_500k's B=1) simply
    # stays replicated (spec entry None) inside the manual region.
    manual = {tp} | set(batch_axes)

    xdims: list = [None] * x.ndim
    if shard_batch:
        xdims[0] = batch_axes
    xdims[-1] = tp
    xspec = P(*xdims)
    wspec = P(fsdp_axis if fsdp_ok else None, tp)
    ospec = xspec

    def fn(xl, wl):
        if fsdp_ok:
            # FSDP-hybrid: gather the out-dim weight shards over data.
            wl = jax.lax.all_gather(wl, fsdp_axis, axis=0, tiled=True)
        return jigsaw_matmul_1d(xl, wl, axis_name=tp, axis_size=p,
                                impl=impl, accum_dtype=accum_dtype,
                                kernel=kernel,
                                mesh_axes=(tuple(mesh.axis_names)
                                           if set(mesh.axis_names) <= manual
                                           else None))

    # check_vma=False: with B=1 (long_500k) the batch stays replicated
    # and VMA inference cannot see through the FSDP all_gather; the
    # equivalence tests (tests/dist_scenarios.py) cover correctness.
    y = shard_map(fn, mesh=mesh, in_specs=(xspec, wspec),
                      out_specs=ospec, axis_names=manual,
                      check_vma=False)(x, w)
    if b is not None:
        y = y + b  # b: [m] sharded on tp -> local add, no comm.
    return y


# --------------------------------------------------------------------------
# 2-D Jigsaw (p x q generalization of the paper's 4-way scheme): Cannon
# --------------------------------------------------------------------------

def _skew(x: jax.Array, amount: jax.Array, axis_name: str, q: int
          ) -> jax.Array:
    """Rotate ``x`` along mesh axis ``axis_name`` by ``amount`` positions
    (towards lower rank), where ``amount`` is a per-rank traced scalar
    (its row/col index).  ppermute applies one static shift; we apply q-1
    conditional shifts so row r accepts exactly r of them."""
    perm = [(i, (i - 1) % q) for i in range(q)]
    for s in range(q - 1):
        shifted = jax.lax.ppermute(x, axis_name, perm)
        x = jnp.where(s < amount, shifted, x)
    return x


def jigsaw_matmul_2d(x: jax.Array, w: jax.Array, *, dom_axis: str,
                     tp_axis: str, dom_size: int, tp_size: int,
                     accum_dtype: Optional[jnp.dtype] = jnp.float32,
                     kernel: str = "xla") -> jax.Array:
    """Manual (inside-shard_map) 2-D Jigsaw matmul via Cannon's algorithm.

    Global math: Y[n, m] = X[n, d] @ W[m, d].T on a (dom=p) x (tp=q) grid
    with p == q (Cannon requires a square grid; the paper's 4-way is the
    2x2 instance).

    Local blocks at grid position (i=dom, j=tp):
      x: [..., n/p, d/q]   block X(i, j)
      w: [m/q, d/p]        block W(m-block j, d-block i)   (transposed
                           Cannon layout -- this is what lets both operands
                           travel along a single mesh axis each)
      y: [..., n/p, m/q]   block Y(i, j)

    Schedule: skew X left by i along tp, skew W up by j along dom, then q
    multiply-accumulate steps, rotating X left and W up by one between
    steps.  Zero redundancy: each rank only ever buffers one remote block
    (the paper's "necessary buffers for communication").
    """
    if dom_size != tp_size:
        raise ValueError(f"2-D Jigsaw needs a square grid, got "
                         f"{dom_size}x{tp_size}")
    q = tp_size
    i = jax.lax.axis_index(dom_axis)
    j = jax.lax.axis_index(tp_axis)

    def mm(a, b):
        # Same [..., k] x [n, k] contraction as the 1-D local block, so
        # the Cannon multiply-accumulate steps ride the kernel knob too.
        # The pallas kernel returns x.dtype (its f32 accumulation is
        # internal); cast back up so the q cross-step partial sums
        # accumulate in accum_dtype on both engines.
        out = _local_matmul(a, b, accum_dtype, kernel)
        return out.astype(accum_dtype) if accum_dtype else out

    a = _skew(x, i, tp_axis, q)     # now holds X(i, (j+i) % q)
    bm = _skew(w, j, dom_axis, q)   # now holds W(j, (i+j) % q)
    acc = mm(a, bm)
    perm_t = [(t, (t - 1) % q) for t in range(q)]
    for _ in range(q - 1):
        a = jax.lax.ppermute(a, tp_axis, perm_t)
        bm = jax.lax.ppermute(bm, dom_axis, perm_t)
        acc = acc + mm(a, bm)
    return acc


def jigsaw_linear_2d(x: jax.Array, w: jax.Array,
                     b: Optional[jax.Array] = None, *, rules: ShardingRules,
                     mesh=None, domain_dim: int = -2,
                     accum_dtype: Optional[jnp.dtype] = jnp.float32,
                     kernel: str = "xla",
                     compute_dtype: Optional[jnp.dtype] = None) -> jax.Array:
    """Public 2-D Jigsaw linear (paper's 4-way, generalized).

    Global layouts:
      x: [..., n, d]  n on ``mdom``, d on ``mtp``
      w: [m, d]       m on ``mtp``,  d on ``mdom``   (Cannon layout)
      y: [..., n, m]  n on ``mdom``, m on ``mtp``  -- same as x: composable.

    Cannon rotates the OPERAND blocks, so the wire format is simply the
    (policy-cast) operand dtype -- bf16 compute halves the skew/rotate
    bytes; the q-step accumulator stays in ``accum_dtype``.
    """
    if not rules.is_2d:
        raise ValueError("jigsaw_linear_2d requires 2-D ShardingRules")
    x, w, b = _cast_operands(x, w, b, compute_dtype)
    dom, tp = rules.dom_axis, rules.tp_axis
    if mesh is None:
        mesh = get_abstract_mesh()
    p, q = mesh.shape[dom], mesh.shape[tp]

    batch_axes = _present_batch_axes(mesh, rules)
    dp = 1
    for a in batch_axes:
        dp *= mesh.shape[a]
    shard_batch = (dp > 1 and x.shape[0] % dp == 0)

    nd = x.ndim
    ddim = domain_dim % nd
    xdims: list = [None] * nd
    if shard_batch and ddim != 0:
        xdims[0] = batch_axes
    xdims[ddim] = dom
    xdims[nd - 1] = tp
    xspec = P(*xdims)
    wspec = P(tp, dom)
    ospec = xspec
    manual = {dom, tp} | set(batch_axes)

    fn = partial(jigsaw_matmul_2d, dom_axis=dom, tp_axis=tp, dom_size=p,
                 tp_size=q, accum_dtype=accum_dtype, kernel=kernel)
    y = shard_map(fn, mesh=mesh, in_specs=(xspec, wspec),
                      out_specs=ospec, axis_names=manual,
                      check_vma=False)(x, w)
    y = y.astype(x.dtype)
    if b is not None:
        y = y + b
    return y


def jigsaw_matmul_2d_t(x: jax.Array, w: jax.Array, *, dom_axis: str,
                       tp_axis: str, dom_size: int, tp_size: int,
                       accum_dtype: Optional[jnp.dtype] = jnp.float32,
                       kernel: str = "xla",
                       mesh_axes: Optional[Tuple[str, ...]] = None
                       ) -> jax.Array:
    """Manual 2-D Jigsaw *transposed* matmul: ``Y = W @ X`` contracting
    X's second-to-last dim.  This is the paper's "transposed MLP" trick
    (§5: implement ``X^T W`` directly instead of transposing) used by the
    WeatherMixer token-mixing MLP: the token dim is contracted *in place*
    with a different communication pattern instead of materializing a
    transpose.

    Local blocks at grid position (i=dom, j=tp):
      x: [..., t/p, c/q]   block X(i, j)     (t = tokens, c = channels)
      w: [m/p, t/q]        block W(m-block i, t-block j)  (natural layout)
      y: [..., m/p, c/q]   block Y(i, j)

    Classic Cannon: skew W left by i along tp, skew X up by j along dom;
    q multiply-accumulate steps rotating W left / X up.

    ``kernel="pallas"`` lowers each multiply-accumulate step to the fused
    ``acc + w @ x`` MXU kernel (kernels/fused_ring.cannon_t_step; one
    pallas_call per step, f32 VMEM accumulation) -- and, on TPU within
    the VMEM budget, fuses the whole q-step loop into ONE pallas_call
    with the rotate hops as in-kernel remote copies.
    """
    if dom_size != tp_size:
        raise ValueError(f"2-D Jigsaw needs a square grid, got "
                         f"{dom_size}x{tp_size}")
    q = tp_size
    i = jax.lax.axis_index(dom_axis)
    j = jax.lax.axis_index(tp_axis)

    if kernel == "pallas":
        from repro.kernels import fused_ring
        wl = _skew(w, i, tp_axis, q)    # W(i, (j+i) % q)
        xl = _skew(x, j, dom_axis, q)   # X((i+j) % q, j)
        return fused_ring.fused_cannon_t(
            wl, xl, dom_axis=dom_axis, tp_axis=tp_axis, q=q,
            accum_dtype=accum_dtype, mesh_axes=mesh_axes)

    def mm(wb, xb):
        # wb: [m_l, t_l]; xb: [..., t_l, c_l] -> [..., m_l, c_l]
        out = jax.lax.dot_general(
            wb, xb, (((1,), (xb.ndim - 2,)), ((), ())),
            preferred_element_type=accum_dtype or xb.dtype)
        # dot_general puts wb's free dim first: [m_l, ..., c_l] -> move it.
        return jnp.moveaxis(out, 0, -2)

    wl = _skew(w, i, tp_axis, q)    # now W(i, (j+i) % q)
    xl = _skew(x, j, dom_axis, q)   # now X((i+j) % q, j)
    acc = mm(wl, xl)
    perm_t = [(t, (t - 1) % q) for t in range(q)]
    for _ in range(q - 1):
        wl = jax.lax.ppermute(wl, tp_axis, perm_t)
        xl = jax.lax.ppermute(xl, dom_axis, perm_t)
        acc = acc + mm(wl, xl)
    return acc


def jigsaw_linear_2d_t(x: jax.Array, w: jax.Array,
                       b: Optional[jax.Array] = None, *,
                       rules: ShardingRules, mesh=None,
                       accum_dtype: Optional[jnp.dtype] = jnp.float32,
                       kernel: str = "xla",
                       compute_dtype: Optional[jnp.dtype] = None
                       ) -> jax.Array:
    """Public 2-D Jigsaw transposed linear: ``y[..., m, c] = w[m, t] @
    x[..., t, c] (+ b[:, None])``.

    Global layouts:
      x: [..., t, c]  t on ``mdom``, c on ``mtp``
      w: [m, t]       m on ``mdom``, t on ``mtp``
      y: [..., m, c]  m on ``mdom``, c on ``mtp``  -- same as x: composable.

    ``kernel="pallas"``: the Cannon multiply-accumulate steps run the
    fused ``acc + w @ x`` MXU kernel (one pallas_call per step; the whole
    loop when the TPU fused variant applies) instead of dot_general.
    """
    if not rules.is_2d:
        raise ValueError("jigsaw_linear_2d_t requires 2-D ShardingRules")
    x, w, b = _cast_operands(x, w, b, compute_dtype)
    dom, tp = rules.dom_axis, rules.tp_axis
    if mesh is None:
        mesh = get_abstract_mesh()
    p, q = mesh.shape[dom], mesh.shape[tp]

    batch_axes = _present_batch_axes(mesh, rules)
    dp = 1
    for a in batch_axes:
        dp *= mesh.shape[a]
    shard_batch = (x.ndim > 2 and dp > 1 and x.shape[0] % dp == 0)

    nd = x.ndim
    xdims: list = [None] * nd
    if shard_batch:
        xdims[0] = batch_axes
    xdims[nd - 2] = dom
    xdims[nd - 1] = tp
    xspec = P(*xdims)
    wspec = P(dom, tp)
    ospec = xspec
    manual = {dom, tp} | set(batch_axes)

    fn = partial(jigsaw_matmul_2d_t, dom_axis=dom, tp_axis=tp, dom_size=p,
                 tp_size=q, accum_dtype=accum_dtype, kernel=kernel,
                 mesh_axes=(tuple(mesh.axis_names)
                            if set(mesh.axis_names) <= manual else None))
    y = shard_map(fn, mesh=mesh, in_specs=(xspec, wspec),
                      out_specs=ospec, axis_names=manual,
                      check_vma=False)(x, w)
    y = y.astype(x.dtype)
    if b is not None:
        y = y + b[:, None]
    return y


# --------------------------------------------------------------------------
# Analytic communication volume (for benchmarks / EXPERIMENTS §Paper-claims)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CommVolume:
    """Bytes sent per device for one linear layer's forward pass."""
    scheme: str
    bytes_per_device: float

def comm_volume_jigsaw_1d(tokens: int, m: int, p: int, dtype_bytes: int = 2
                          ) -> CommVolume:
    # ring reduce-scatter of [tokens, m]: (p-1) chunks of tokens*m/p each.
    return CommVolume("jigsaw-1d", (p - 1) / p * tokens * m * dtype_bytes)

def comm_volume_megatron_pair(tokens: int, d: int, p: int,
                              dtype_bytes: int = 2) -> CommVolume:
    # Megatron fuses two linears around one allreduce of [tokens, d]:
    # ring allreduce = 2 (p-1)/p * bytes.
    return CommVolume("megatron-pair", 2 * (p - 1) / p * tokens * d * dtype_bytes)

@dataclasses.dataclass(frozen=True)
class CommSchedule:
    """Per-hop accounting of an explicit ring schedule (one linear fwd).

    ``flops_per_hop`` is the local GEMM work the schedule exposes
    *between* consecutive sends -- the compute available to hide each
    hop.  The monolithic ``ring`` finishes its single GEMM before hop 0,
    so it exposes zero overlappable work; ``ring_chunked`` exposes one
    output-chunk GEMM per hop (the paper's overlap).
    """
    scheme: str
    hops: int
    bytes_per_hop: float
    flops_per_hop: float
    bytes_per_device: float

    def overlap_ratio(self, ici_bw: float, peak_flops: float) -> float:
        """compute-time / comm-time per hop (>= 1: the hop is hidden)."""
        if self.bytes_per_hop == 0:
            return float("inf")
        t_comm = self.bytes_per_hop / ici_bw
        t_comp = self.flops_per_hop / peak_flops
        return t_comp / t_comm if t_comm else float("inf")


def comm_schedule_jigsaw_1d(tokens: int, m: int, d_local: int, p: int,
                            dtype_bytes: int = 2, chunked: bool = True,
                            impl: Optional[str] = None) -> CommSchedule:
    """Hop-level schedule of the explicit 1-D Jigsaw ring.

    All three schedules move the same (p-1)/p * tokens * m bytes per
    device; they differ in what compute is still pending while each hop's
    send is in flight:

      ring         : nothing (the single GEMM finished before hop 0),
      ring_chunked : one output-chunk GEMM (2 * tokens * d_local * m/p
                     flops) -- *exposed to* XLA's scheduler, overlap
                     best-effort,
      ring_fused   : the same chunk GEMM plus the hop add (tokens * m/p
                     VPU flops), executed *inside* the kernel while the
                     RDMA flies -- overlap guaranteed by construction.

    ``impl`` ("ring" | "ring_chunked" | "ring_fused") supersedes the
    legacy ``chunked`` bool when given.
    """
    if impl is None:
        impl = "ring_chunked" if chunked else "ring"
    if impl not in ("ring", "ring_chunked", "ring_fused"):
        raise ValueError(f"comm_schedule_jigsaw_1d: unknown impl {impl!r}")
    hop_bytes = tokens * (m / p) * dtype_bytes
    chunk_flops = 2.0 * tokens * d_local * (m / p)
    flops = {"ring": 0.0, "ring_chunked": chunk_flops,
             "ring_fused": chunk_flops + tokens * (m / p)}[impl]
    return CommSchedule(
        scheme="jigsaw-1d-" + impl,
        hops=p - 1, bytes_per_hop=hop_bytes,
        flops_per_hop=flops,
        bytes_per_device=(p - 1) * hop_bytes)


def comm_volume_jigsaw_2d(tokens: int, m: int, q: int, dtype_bytes: int = 2
                          ) -> CommVolume:
    # Cannon on q x q grid: per step each rank forwards its X block
    # [tokens/q, d/q] and W block [m/q, d/q]; 2(q-1) block sends + skews.
    # Expressed in output-proportional terms for comparability.
    blk = tokens / q * m / q
    return CommVolume("jigsaw-2d", 2 * (q - 1) * blk * dtype_bytes)
