"""Mesh axes and sharding rules for Jigsaw parallelism.

Jigsaw (Kieckhefen et al., 2025) shards BOTH the data sample (domain
parallelism) and the weights / optimizer states (tensor parallelism) with
zero memory redundancy: no parameter is ever allgathered onto one device.

On TPU we realize this with a named mesh:

    single-pod : (data=16, model=16)                   -- 256 chips
    multi-pod  : (pod=2, data=16, model=16)            -- 512 chips

The ``model`` axis carries the Jigsaw sharding:

  * 1-D Jigsaw (paper's 2-way, generalized to n-way): every weight matrix
    is sharded along its *contracting* dimension, activations along their
    last (channel/feature) dimension; each linear layer completes the
    contraction with a reduce-scatter (ring of partial sums -- exactly the
    paper's overlap schedule, executed by the ICI).

  * 2-D Jigsaw (paper's 4-way, generalized to p x q): the ``model`` axis is
    factored into (``mdom``, ``mtp``); activations are sharded over
    (domain-dim x channel-dim) and weights over (out-features x
    in-features), and the contraction runs Cannon's algorithm.

``pod`` and ``data`` are pure data-parallel axes: gradients are psum'd over
them, parameters are replicated over them (optionally ZeRO-1 sharded --
a beyond-paper extension).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Canonical axis names.
POD_AXIS = "pod"
DATA_AXIS = "data"
MODEL_AXIS = "model"
# Factored model axis for 2-D Jigsaw.
MDOM_AXIS = "mdom"  # domain (spatial / token) sub-axis
MTP_AXIS = "mtp"    # tensor (channel / feature) sub-axis


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Names of the mesh axes used for each parallelism role.

    ``batch_axes`` are the pure data-parallel axes (gradient reduction).
    ``model_axes`` carry Jigsaw.  For 1-D Jigsaw ``model_axes`` is a single
    axis; for 2-D it is ``(mdom, mtp)``.
    """

    batch_axes: Tuple[str, ...] = (DATA_AXIS,)
    model_axes: Tuple[str, ...] = (MODEL_AXIS,)

    @property
    def is_2d(self) -> bool:
        return len(self.model_axes) == 2

    @property
    def tp_axis(self) -> str:
        """The channel/feature (tensor-parallel) axis."""
        return self.model_axes[-1]

    @property
    def dom_axis(self) -> Optional[str]:
        """The domain (spatial/token) axis, if 2-D."""
        return self.model_axes[0] if self.is_2d else None

    # ---- canonical PartitionSpecs ------------------------------------
    def batch(self, *trailing) -> P:
        """Spec for an activation whose dim 0 is the (global) batch."""
        return P(self.batch_axes, *trailing)

    def act(self, ndim: int, *, domain_dim: Optional[int] = None,
            feature_dim: int = -1) -> P:
        """Activation spec: batch on batch_axes, feature dim on tp axis,
        and (for 2-D Jigsaw) the domain dim on the dom axis."""
        dims: list = [None] * ndim
        dims[0] = self.batch_axes
        dims[feature_dim % ndim] = self.tp_axis
        if self.is_2d and domain_dim is not None:
            dims[domain_dim % ndim] = self.dom_axis
        return P(*dims)

    def weight(self, ndim: int = 2, *, contracting_dim: int = -1,
               out_dim: int = 0) -> P:
        """Jigsaw weight spec.

        1-D: shard the contracting dim on the tp axis (zero redundancy,
        reduce-scatter completes the matmul).
        2-D (Cannon layout): out-features on ``mtp``, in-features on
        ``mdom`` -- see core/jigsaw.py for why the layout is transposed.
        """
        dims: list = [None] * ndim
        if self.is_2d:
            dims[out_dim % ndim] = self.tp_axis
            dims[contracting_dim % ndim] = self.dom_axis
        else:
            dims[contracting_dim % ndim] = self.tp_axis
        return P(*dims)

    def replicated(self, ndim: int = 1) -> P:
        return P(*([None] * ndim))


# A default 1-D rule set, used throughout the configs.
RULES_1D = ShardingRules()
RULES_2D = ShardingRules(model_axes=(MDOM_AXIS, MTP_AXIS))


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def axis_size(mesh: Mesh, axes) -> int:
    """Product of the mesh extents of ``axes`` (str or tuple)."""
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return math.prod(mesh.shape[a] for a in axes)


def pad_to_multiple(n: int, multiple: int) -> int:
    return ((n + multiple - 1) // multiple) * multiple


def divisible(n: int, p: int) -> bool:
    return n % p == 0


def constrain(x, spec: P):
    """with_sharding_constraint that is a no-op outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x
