"""JAX version-compatibility shims (single import point for jax API drift).

The codebase is written against the modern jax public API:

  * ``jax.shard_map`` (with ``axis_names=`` / ``check_vma=``)
  * ``jax.set_mesh`` context manager + ``jax.sharding.get_abstract_mesh``
  * ``jax.make_mesh(..., axis_types=...)`` and ``jax.sharding.AxisType``
  * ``jax.P`` (alias of ``jax.sharding.PartitionSpec``)
  * ``jax.tree.map``

The pinned environment ships jax 0.4.37, which has the same functionality
under older spellings (``jax.experimental.shard_map`` with ``check_rep=`` /
``auto=``, the legacy ``with mesh:`` resource env, no axis types).  This
module exposes canonical names for all of them and, on import, installs any
*missing* attribute onto the ``jax`` / ``jax.sharding`` namespaces so call
sites written for newer jax run unmodified.  On a modern jax nothing is
patched -- every shim defers to the native symbol when present.

Import ``repro`` (the package __init__ imports this module) or import the
names directly:

    from repro.compat import set_mesh, shard_map, make_mesh, P
"""
from __future__ import annotations

import contextlib
import enum
import inspect
from typing import Optional

import jax
import jax.sharding
from jax.sharding import Mesh, PartitionSpec

P = PartitionSpec

_HAS_SET_MESH = hasattr(jax, "set_mesh")
_HAS_SHARD_MAP = hasattr(jax, "shard_map")
_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
_HAS_ABSTRACT_MESH = hasattr(jax.sharding, "get_abstract_mesh")


# ---------------------------------------------------------------------------
# AxisType
# ---------------------------------------------------------------------------

if _HAS_AXIS_TYPE:
    AxisType = jax.sharding.AxisType
else:
    class AxisType(enum.Enum):
        """Stand-in for ``jax.sharding.AxisType`` (jax >= 0.6).  Old jax
        treats every mesh axis as Auto, so the value is advisory only."""
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


# ---------------------------------------------------------------------------
# make_mesh
# ---------------------------------------------------------------------------

_native_make_mesh = jax.make_mesh
_accepts_axis_types = (
    "axis_types" in inspect.signature(_native_make_mesh).parameters)


def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
    """``jax.make_mesh`` accepting ``axis_types`` on every jax version."""
    if _accepts_axis_types:
        return _native_make_mesh(axis_shapes, axis_names, devices=devices,
                                 axis_types=axis_types)
    return _native_make_mesh(axis_shapes, axis_names, devices=devices)


# ---------------------------------------------------------------------------
# set_mesh / get_abstract_mesh
# ---------------------------------------------------------------------------

_MESH_STACK: list = []


class _EmptyMesh:
    """Mimics the empty abstract mesh: ``axis in mesh.shape`` is False."""
    shape: dict = {}
    axis_names: tuple = ()
    empty = True


_EMPTY_MESH = _EmptyMesh()

if _HAS_SET_MESH:
    set_mesh = jax.set_mesh
else:
    @contextlib.contextmanager
    def set_mesh(mesh: Mesh):
        """Context manager equivalent of ``jax.set_mesh`` for old jax.

        Tracks the mesh so ``get_abstract_mesh`` can see it from inside a
        trace, and enters the legacy ``with mesh:`` resource env so bare
        ``PartitionSpec``s work in ``with_sharding_constraint``."""
        _MESH_STACK.append(mesh)
        try:
            with mesh:
                yield mesh
        finally:
            _MESH_STACK.pop()

if _HAS_ABSTRACT_MESH:
    get_abstract_mesh = jax.sharding.get_abstract_mesh
else:
    def get_abstract_mesh():
        """Innermost mesh set via :func:`set_mesh` (a *concrete* Mesh --
        shard_map accepts it wherever the abstract mesh is used)."""
        if _MESH_STACK:
            return _MESH_STACK[-1]
        try:
            from jax._src import mesh as mesh_lib
            m = mesh_lib.thread_resources.env.physical_mesh
            if m is not None and len(m.shape):
                return m
        except Exception:
            pass
        return _EMPTY_MESH


def current_mesh() -> Optional[Mesh]:
    """The mesh set via set_mesh, or None (works on every jax version)."""
    m = get_abstract_mesh()
    if m is None or getattr(m, "empty", False) or not len(m.shape):
        return None
    return m


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

if _HAS_SHARD_MAP:
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _old_shard_map

    def shard_map(f, *, mesh=None, in_specs, out_specs, axis_names=None,
                  check_vma=None, check_rep=None):
        """Modern ``jax.shard_map`` signature on old jax.

        ``check_vma`` maps to ``check_rep``.  Modern jax treats mesh axes
        outside ``axis_names`` as Auto (compiler-managed); old XLA cannot
        mix manual+auto regions here ("PartitionId is not supported for
        SPMD partitioning"), so every axis is made manual instead: axes
        unmentioned in the specs are then simply replicated, which is
        exactly how this codebase uses partial ``axis_names`` (see
        jigsaw_linear: batch axes are always listed explicitly)."""
        if mesh is None:
            mesh = get_abstract_mesh()
        if axis_names is not None:
            unknown = frozenset(axis_names) - frozenset(mesh.axis_names)
            if unknown:
                raise ValueError(f"axis_names {unknown} not in mesh "
                                 f"{tuple(mesh.axis_names)}")
        check = True
        if check_vma is not None:
            check = check_vma
        elif check_rep is not None:
            check = check_rep
        return _old_shard_map(f, mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check)


# ---------------------------------------------------------------------------
# install missing attributes onto the jax namespaces
# ---------------------------------------------------------------------------

def install() -> None:
    """Patch old-jax namespaces with the modern spellings (idempotent; a
    no-op on jax versions that already provide them natively)."""
    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = set_mesh
    if not hasattr(jax, "shard_map"):
        jax.shard_map = shard_map
    if not hasattr(jax, "P"):
        jax.P = PartitionSpec
    if not _accepts_axis_types:
        jax.make_mesh = make_mesh
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = AxisType
    if not hasattr(jax.sharding, "get_abstract_mesh"):
        jax.sharding.get_abstract_mesh = get_abstract_mesh
    if not hasattr(jax.sharding, "use_mesh"):
        jax.sharding.use_mesh = set_mesh


install()
