"""Synthetic token-stream pipeline for the LM architectures.

Deterministic, learnable structure: an affine congruential walk with
random restarts -- next-token prediction has low achievable entropy, so
smoke-training shows real loss decrease without any external data.

Every *row* is a pure function of its global sample index
``step * batch_size + i`` (its own ``SeedSequence`` stream), so a
data-parallel rank can generate exactly the rows it owns
(``sample_shard``) and the result is bit-identical to slicing the full
batch -- the token-stream analogue of the weather pipeline's
domain-parallel read (paper §5).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenDataConfig:
    vocab_size: int
    seq_len: int
    seed: int = 0
    restart_p: float = 0.05


class TokenDataset:
    def __init__(self, cfg: TokenDataConfig):
        self.cfg = cfg

    def _rows(self, idx: np.ndarray) -> dict:
        """Generate the rows with global sample indices ``idx``."""
        c = self.cfg
        v = c.vocab_size
        a, b = 31, 17
        rngs = [np.random.default_rng(
            np.random.SeedSequence([c.seed, 7, int(s)])) for s in idx]
        x = np.zeros((len(idx), c.seq_len + 1), np.int64)
        x[:, 0] = [r.integers(0, v) for r in rngs]
        restarts = np.stack([r.random(c.seq_len) < c.restart_p
                             for r in rngs]) if len(idx) else \
            np.zeros((0, c.seq_len), bool)
        fresh = np.stack([r.integers(0, v, c.seq_len) for r in rngs]) \
            if len(idx) else np.zeros((0, c.seq_len), np.int64)
        for t in range(c.seq_len):
            nxt = (x[:, t] * a + b) % v
            x[:, t + 1] = np.where(restarts[:, t], fresh[:, t], nxt)
        return {"tokens": x[:, :-1].astype(np.int32),
                "labels": x[:, 1:].astype(np.int32)}

    def sample_batch(self, step: int, batch_size: int) -> dict:
        idx = np.arange(batch_size, dtype=np.int64) + step * batch_size
        return self._rows(idx)

    def sample_shard(self, step: int, batch_size: int,
                     row_slice: slice = slice(None)) -> dict:
        """Per-data-rank sharded read: only ``row_slice`` of the global
        batch; bit-identical to slicing ``sample_batch`` (each row has
        its own deterministic stream)."""
        idx = (np.arange(batch_size, dtype=np.int64)
               + step * batch_size)[row_slice]
        return self._rows(idx)

    def io_bytes_per_rank(self, batch_size: int, n_ranks: int) -> int:
        """Modeled I/O per data-parallel rank per step (tokens + labels,
        int32): row sharding divides the read by the rank count."""
        return 2 * 4 * batch_size * self.cfg.seq_len // n_ranks
