"""Synthetic token-stream pipeline for the LM architectures.

Deterministic, learnable structure: an affine congruential walk with
random restarts -- next-token prediction has low achievable entropy, so
smoke-training shows real loss decrease without any external data.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenDataConfig:
    vocab_size: int
    seq_len: int
    seed: int = 0
    restart_p: float = 0.05


class TokenDataset:
    def __init__(self, cfg: TokenDataConfig):
        self.cfg = cfg

    def sample_batch(self, step: int, batch_size: int) -> dict:
        c = self.cfg
        rng = np.random.default_rng(np.random.SeedSequence([c.seed, step]))
        v = c.vocab_size
        a, b = 31, 17
        x = np.zeros((batch_size, c.seq_len + 1), np.int64)
        x[:, 0] = rng.integers(0, v, batch_size)
        restarts = rng.random((batch_size, c.seq_len)) < c.restart_p
        fresh = rng.integers(0, v, (batch_size, c.seq_len))
        for t in range(c.seq_len):
            nxt = (x[:, t] * a + b) % v
            x[:, t + 1] = np.where(restarts[:, t], fresh[:, t], nxt)
        return {"tokens": x[:, :-1].astype(np.int32),
                "labels": x[:, 1:].astype(np.int32)}
