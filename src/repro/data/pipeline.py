"""Pluggable input-pipeline subsystem: domain-parallel sharded reads with
background prefetch (the paper's §5 data-loading contribution as the
*actual training path*, not just a property test).

Every model-parallel rank reads only its own (longitude x channel)
partition of each weather sample -- and every data-parallel rank only its
own batch rows -- so host-side generation ("I/O") scales with the number
of ranks: the source of the paper's superscalar weak scaling in
I/O-bandwidth-limited systems.  A background thread generates and
transfers the next batches while the device computes the current step
(double-buffered prefetch), overlapping input with compute.

Three pieces (DESIGN.md §7):

* ``BatchSource``       -- dataset adapter exposing full-batch and
                           per-shard reads that are bit-identical to
                           slicing the full batch.
* ``InputPipeline``     -- derives a per-HOST read plan from the mesh +
                           batch PartitionSpecs (the UNIQUE index slices
                           across this host's addressable devices,
                           computed once since specs are step-invariant),
                           reads each unique slice exactly once per step,
                           fans it out to the devices that replicate it,
                           assembles the global jax.Array with
                           ``make_array_from_single_device_arrays``, and
                           (optionally) prefetches on a worker thread.
                           ``mode="sync-full"`` preserves the legacy
                           generate-everything-then-device_put behavior
                           for A/B benchmarking.
* ``make_pipeline``     -- family dispatch (mixer / lm / vlm / audio).

Determinism: batches are a pure function of (seed, step, horizon); the
prefetch thread changes timing only, never values (property-tested in
tests/test_pipeline.py and tests/dist_scenarios.py).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterable, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# hashable (start, stop) bounds per dim from a sharding index tuple
# (``slice`` is unhashable on py<3.12); shared with the checkpoint
# subsystem, which records the same bounds in its manifest
from repro import telemetry
from repro.checkpoint.manifest import normalize_index as _normalize_index
from repro.data.tokens import TokenDataConfig, TokenDataset
from repro.data.weather import WeatherDataConfig, WeatherDataset


# ---------------------------------------------------------------------------
# Stats
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PipelineStats:
    """Host-side I/O accounting, updated by the pipeline once per batch.

    ``generated_bytes[key]``  bytes actually produced by shard reads on
                              this host (deduplicated across devices that
                              own identical replicas);
    ``rank_bytes[key][dev]``  logical bytes each device's rank read --
                              this is what ``io_bytes_per_rank`` models
                              and what the ∝ 1/ranks test measures.

    Updates go through :meth:`record_batch`, which holds the process
    tracer's lock for the whole batch: the prefetch worker and a
    same-process consumer (stats readers, a ``sync-full`` A/B run) never
    interleave read-modify-writes on these counters, and the aggregate
    totals land in the tracer's counter table in the same critical
    section (one lock acquisition per batch, not one per device read).
    """
    steps: int = 0
    plan_builds: int = 0
    generated_bytes: Dict[str, int] = dataclasses.field(default_factory=dict)
    rank_bytes: Dict[str, Dict[int, int]] = dataclasses.field(
        default_factory=dict)

    def record(self, key: str, device_id: int, nbytes: int,
               generated: bool) -> None:
        """Single-read back-compat shim; prefer :meth:`record_batch`."""
        self.record_batch([(key, device_id, nbytes, generated)])

    def record_batch(self, reads: Sequence[Tuple[str, int, int, bool]],
                     steps: int = 0, plan_builds: int = 0) -> None:
        """Apply one batch's worth of read records ``(key, device_id,
        nbytes, generated)`` atomically under the telemetry lock."""
        gen = 0
        dev_bytes = 0
        tr = telemetry.get_tracer()
        with tr.lock:
            self.steps += steps
            self.plan_builds += plan_builds
            for key, device_id, nbytes, generated in reads:
                if generated:
                    self.generated_bytes[key] = (
                        self.generated_bytes.get(key, 0) + nbytes)
                    gen += nbytes
                per = self.rank_bytes.setdefault(key, {})
                per[device_id] = per.get(device_id, 0) + nbytes
                dev_bytes += nbytes
            updates = {}
            if steps:
                updates["pipeline.batches"] = steps
            if plan_builds:
                updates["pipeline.plan_builds"] = plan_builds
            if gen:
                updates["pipeline.generated_bytes"] = gen
            if dev_bytes:
                updates["pipeline.device_bytes"] = dev_bytes
            if updates:
                tr.add_counters_locked(updates)


# ---------------------------------------------------------------------------
# Batch sources (dataset adapters)
# ---------------------------------------------------------------------------

class BatchSource:
    """Adapter between a synthetic dataset and the pipeline.

    ``read_key(key, step, horizon, idx)`` must be bit-identical to
    ``full_batch(step, horizon)[key][idx]`` -- the paper's data-loading
    correctness invariant."""

    keys: Tuple[str, ...] = ()

    def full_batch(self, step: int, horizon: int) -> Dict[str, np.ndarray]:
        raise NotImplementedError

    def read_key(self, key: str, step: int, horizon: int,
                 idx: Tuple[Tuple[int, int], ...]) -> np.ndarray:
        """``idx`` is a per-dim tuple of (start, stop) bounds."""
        raise NotImplementedError

    def key_shape(self, key: str) -> Tuple[int, ...]:
        """Global shape of ``key`` (step-invariant)."""
        raise NotImplementedError


class WeatherBatchSource(BatchSource):
    """ERA5-like fields: true partitioned reads over (rows, lat, lon,
    channels) -- each rank evaluates only its sub-grid."""

    keys = ("fields", "target")

    def __init__(self, ds: WeatherDataset, batch_size: int):
        self.ds = ds
        self.batch_size = batch_size
        self._memo_key = None
        self._memo: Dict[Tuple, Dict[str, np.ndarray]] = {}

    def full_batch(self, step, horizon):
        return self.ds.sample_batch(step, self.batch_size, horizon=horizon)

    def key_shape(self, key):
        c = self.ds.cfg
        return (self.batch_size, c.lat, c.lon, c.channels)

    def read_key(self, key, step, horizon, idx):
        # fields and target share shape/spec, hence the same index map:
        # one sample_shard call serves both (memoized per step).
        if self._memo_key != (step, horizon):
            self._memo_key = (step, horizon)
            self._memo = {}
        got = self._memo.get(idx)
        if got is None:
            b, la, lo, ch = _slices(idx)
            got = self.ds.sample_shard(
                step, self.batch_size, row_slice=b, lat_slice=la,
                lon_slice=lo, chan_slice=ch, horizon=horizon)
            self._memo[idx] = got
        return got[key]


class TokenBatchSource(BatchSource):
    """LM token rows (+ optional dense side inputs for vlm/audio): true
    per-data-rank row reads for tokens/labels; the dense ``embeds`` /
    ``frames`` are a full host draw sliced per device (they model
    preprocessed modality features, not the paper's grid I/O)."""

    def __init__(self, ds: TokenDataset, batch_size: int,
                 extras: Optional[Dict[str, Tuple[int, ...]]] = None):
        self.ds = ds
        self.batch_size = batch_size
        self.extras = dict(extras or {})   # name -> trailing shape
        self.keys = ("tokens", "labels") + tuple(self.extras)
        self._memo_key = None
        self._rows: Dict[Tuple[int, int], Dict[str, np.ndarray]] = {}
        self._full_extras: Dict[str, np.ndarray] = {}

    def _sync_step(self, step: int) -> None:
        """Invalidate the per-step memos when the step changes (both the
        full-batch and the sharded read path go through here)."""
        if self._memo_key != step:
            self._memo_key = step
            self._rows = {}
            self._full_extras = {}

    def _extra(self, key: str, step: int) -> np.ndarray:
        self._sync_step(step)
        got = self._full_extras.get(key)
        if got is None:
            rng = np.random.default_rng(step)
            got = rng.normal(0, 1, (self.batch_size,) + self.extras[key]
                             ).astype(np.float32)
            self._full_extras[key] = got
        return got

    def full_batch(self, step, horizon):
        del horizon
        out = self.ds.sample_batch(step, self.batch_size)
        for k in self.extras:
            out[k] = self._extra(k, step)
        return out

    def key_shape(self, key):
        if key in self.extras:
            return (self.batch_size,) + self.extras[key]
        return (self.batch_size, self.ds.cfg.seq_len)

    def read_key(self, key, step, horizon, idx):
        del horizon
        self._sync_step(step)
        if key in self.extras:
            return np.ascontiguousarray(self._extra(key, step)[_slices(idx)])
        rows = idx[0]
        got = self._rows.get(rows)
        if got is None:
            got = self.ds.sample_shard(step, self.batch_size,
                                       row_slice=slice(*rows))
            self._rows[rows] = got
        return got[key]


# ---------------------------------------------------------------------------
# The pipeline
# ---------------------------------------------------------------------------



def _slices(nidx: Tuple[Tuple[int, int], ...]) -> Tuple[slice, ...]:
    return tuple(slice(a, b) for a, b in nidx)


@dataclasses.dataclass(frozen=True)
class _ReadPlan:
    """Per-host read plan for one batch key: the UNIQUE index slices any
    addressable device needs, each with the devices that replicate it.
    Built once per pipeline (specs and shapes are step-invariant), so
    multi-replica meshes never read the same slice once per local
    device -- only once per host."""
    shape: Tuple[int, ...]
    sharding: NamedSharding
    reads: Tuple[Tuple[Tuple[Tuple[int, int], ...], Tuple], ...]


class InputPipeline:
    """Domain-parallel, prefetching input pipeline.

    Parameters
    ----------
    source : BatchSource
    mesh : Mesh or None -- None means single-device (no sharding).
    specs : dict key -> PartitionSpec (global batch layout, unsanitized);
        required when ``mesh`` is given.
    mode : "sharded" (per-rank partitioned reads, the paper's path) or
        "sync-full" (generate the full global batch then device_put --
        the legacy behavior, kept for A/B benchmarking).
    prefetch : number of batches the background thread keeps in flight
        (0 disables the thread; 2 = double buffering).
    """

    def __init__(self, source: BatchSource, *, mesh: Optional[Mesh] = None,
                 specs: Optional[Dict[str, P]] = None, mode: str = "sharded",
                 prefetch: int = 2):
        if mode not in ("sharded", "sync-full"):
            raise ValueError(f"unknown pipeline mode {mode!r}")
        if mesh is not None and specs is None:
            raise ValueError("specs required when a mesh is given")
        self.source = source
        self.mesh = mesh
        self.specs = specs or {}
        self.mode = mode
        self.prefetch = int(prefetch)
        self.stats = PipelineStats()
        # next step this pipeline will serve (checkpointed + restored by
        # the engine for exact resume; batches are pure functions of the
        # step so the cursor IS the full pipeline state)
        self.cursor = 0
        self._plans: Dict[str, _ReadPlan] = {}
        # live prefetch machinery of the most recent iterate() (for
        # stop(): a preempting process must be able to cancel the worker
        # without waiting out the full horizon)
        self._queue: Optional["queue.Queue"] = None
        self._stop_event: Optional[threading.Event] = None
        self._thread: Optional[threading.Thread] = None

    # -- host-side ------------------------------------------------------
    def host_batch(self, step: int, horizon: int = 1
                   ) -> Dict[str, np.ndarray]:
        """The full global batch on host (reference / sync-full path)."""
        return self.source.full_batch(step, horizon)

    def _sharding_for(self, key: str, shape) -> NamedSharding:
        from repro.launch import specs as S
        spec = S.sanitize_spec(shape, self.specs.get(key, P()), self.mesh)
        return NamedSharding(self.mesh, spec)

    # -- device-side ----------------------------------------------------
    def get(self, step: int, horizon: int = 1) -> Dict[str, jax.Array]:
        """The global (possibly sharded) device batch for ``step``.

        Stats are collected locally while reading and committed in ONE
        ``record_batch`` call at the end -- the whole batch's accounting
        is a single critical section, so a concurrent stats reader never
        observes a half-applied batch."""
        reads: list = []
        if self.mesh is None:
            out = {k: jnp.asarray(v)
                   for k, v in self.host_batch(step, horizon).items()}
        elif self.mode == "sync-full":
            hb = self.host_batch(step, horizon)
            reads.extend((k, -1, v.nbytes, True) for k, v in hb.items())
            out = {k: jax.device_put(jnp.asarray(v),
                                     self._sharding_for(k, v.shape))
                   for k, v in hb.items()}
        else:
            out = {k: self._assemble(k, step, horizon, reads)
                   for k in self.source.keys}
        self.stats.record_batch(reads, steps=1)
        return out

    def _plan_for(self, key: str) -> _ReadPlan:
        """The (cached) per-host read plan for ``key``: unique slices
        across this host's addressable devices, grouped."""
        plan = self._plans.get(key)
        if plan is None:
            shape = self.source.key_shape(key)
            sharding = self._sharding_for(key, shape)
            idx_map = sharding.addressable_devices_indices_map(shape)
            groups: Dict[Tuple[Tuple[int, int], ...], list] = {}
            for dev, idx in idx_map.items():
                groups.setdefault(_normalize_index(idx, shape),
                                  []).append(dev)
            plan = _ReadPlan(shape, sharding,
                             tuple((nidx, tuple(devs))
                                   for nidx, devs in groups.items()))
            self._plans[key] = plan
            self.stats.record_batch([], plan_builds=1)
        return plan

    def _assemble(self, key: str, step: int, horizon: int,
                  reads: list) -> jax.Array:
        """Build the global array from per-host partitioned reads: each
        unique slice in the plan is generated ONCE and fanned out to
        every device that replicates it.  Read records are appended to
        ``reads`` for the caller's one-shot ``record_batch``."""
        plan = self._plan_for(key)
        arrays = []
        for nidx, devs in plan.reads:
            buf = np.ascontiguousarray(
                self.source.read_key(key, step, horizon, nidx))
            for j, dev in enumerate(devs):
                reads.append((key, dev.id, buf.nbytes, j == 0))
                arrays.append(jax.device_put(buf, dev))
        return jax.make_array_from_single_device_arrays(
            plan.shape, plan.sharding, arrays)

    # -- resume state ----------------------------------------------------
    def state(self) -> Dict[str, int]:
        """Checkpointable cursor (batches are pure functions of the step,
        so this one integer restarts the stream exactly)."""
        return {"cursor": int(self.cursor)}

    def set_state(self, state: Dict[str, int]) -> None:
        self.cursor = int(state["cursor"])

    # -- prefetching iterator -------------------------------------------
    def iterate(self, horizons: Sequence[int],
                start_step: Optional[int] = None
                ) -> Iterable[Dict[str, jax.Array]]:
        """Yield device batches for steps ``start_step + i`` with per-step
        rollout horizons ``horizons[i]``.  ``start_step=None`` continues
        from the pipeline's cursor (0 on a fresh pipeline, the restored
        step after a resume).  With ``prefetch > 0`` a daemon thread
        generates and transfers batches ahead of the consumer; values
        are identical either way (pure function of the step)."""
        n = len(horizons)
        if start_step is None:
            start_step = self.cursor
        if self.prefetch <= 0:
            for i in range(n):
                batch = self.get(start_step + i, int(horizons[i]))
                self.cursor = start_step + i + 1
                yield batch
            return

        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()
        tr = telemetry.get_tracer()

        def worker():
            try:
                for i in range(n):
                    if stop.is_set():
                        return
                    with tr.span("pipeline.produce", step=start_step + i):
                        batch = self.get(start_step + i, int(horizons[i]))
                    while not stop.is_set():
                        # bounded put: never blocks forever against a
                        # consumer that has already given up (stop()
                        # from a preempting process)
                        try:
                            q.put((batch, None), timeout=0.1)
                            break
                        except queue.Full:
                            continue
            except BaseException as e:       # surfaced on the consumer
                q.put((None, e))

        t = threading.Thread(target=worker, name="input-pipeline",
                             daemon=True)
        self._queue, self._stop_event, self._thread = q, stop, t
        t.start()
        try:
            for i in range(n):
                # depth BEFORE the blocking get: the signal the engine's
                # data_wait spans are cross-checked against (0 here means
                # the consumer is about to stall on the producer)
                tr.gauge("pipeline.queue_depth", q.qsize())
                batch, err = q.get()
                if err is not None:
                    raise err
                self.cursor = start_step + i + 1
                yield batch
        finally:
            self.stop()

    def stop(self, timeout: float = 5.0) -> bool:
        """Cancel the prefetch worker: set its stop flag, drain the
        queue so a blocked ``put`` wakes up, and join with ``timeout``.
        Returns True when the thread is down (always safe to call --
        idempotent, and a no-op when prefetch is disabled).  The worker
        is a daemon, so even a join timeout (it only happens mid-
        ``get()``, i.e. mid batch generation) cannot hang process exit
        -- the preemption path needs bounded shutdown latency."""
        t, q, stop = self._thread, self._queue, self._stop_event
        if t is None:
            return True
        stop.set()
        while True:                          # unblock a producer in put()
            try:
                q.get_nowait()
            except queue.Empty:
                break
        t.join(timeout=timeout)
        alive = t.is_alive()
        if not alive:
            self._queue = self._stop_event = self._thread = None
        return not alive

    # -- modeled I/O -----------------------------------------------------
    def io_bytes_per_rank(self, n_ranks: int) -> int:
        """Modeled per-rank bytes per step for the primary array (delegates
        to the dataset's model; compared against measured ``stats`` in
        tests)."""
        ds, bsz = self.source.ds, self.source.batch_size
        return ds.io_bytes_per_rank(bsz, n_ranks)


# ---------------------------------------------------------------------------
# Family dispatch
# ---------------------------------------------------------------------------

def make_source(cfg, batch_size: int, seq_len: int = 128,
                seed: int = 0) -> BatchSource:
    """BatchSource for a ModelConfig family (mixer / lm / vlm / audio)."""
    if cfg.family == "mixer":
        ds = WeatherDataset(WeatherDataConfig(
            lat=cfg.wm_lat, lon=cfg.wm_lon, channels=cfg.wm_channels,
            seed=seed))
        return WeatherBatchSource(ds, batch_size)
    ds = TokenDataset(TokenDataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=seq_len, seed=seed))
    extras: Dict[str, Tuple[int, ...]] = {}
    if cfg.family == "vlm":
        extras["embeds"] = (cfg.n_patches, cfg.d_model)
    if cfg.family == "audio":
        extras["frames"] = (cfg.n_frames, cfg.d_model)
    return TokenBatchSource(ds, batch_size, extras)


def make_pipeline(cfg, *, mesh: Optional[Mesh] = None, rules=None,
                  batch_size: int, seq_len: int = 128, mode: str = "sharded",
                  prefetch: int = 2, seed: int = 0) -> InputPipeline:
    """InputPipeline for a ModelConfig on ``mesh`` (None = single device)."""
    source = make_source(cfg, batch_size, seq_len=seq_len, seed=seed)
    specs = None
    if mesh is not None:
        from repro.launch import specs as S
        specs = S.batch_specs(cfg, rules)
    return InputPipeline(source, mesh=mesh, specs=specs, mode=mode,
                         prefetch=prefetch)
