"""Synthetic ERA5-like data pipeline with *domain-parallel* loading.

The paper's data-loading contribution (§5): every model-parallel rank
reads only its own (longitude x channel) partition of each sample, so
I/O bandwidth scales with the number of ranks (the source of the paper's
superscalar weak scaling).

We reproduce that property with a synthetic-but-deterministic generator:
each sample is a superposition of smooth spherical-harmonic-ish modes
whose coefficients are a pure function of (seed, sample_index, channel).
Because every grid point is an *independent closed form* of its indices,
``sample_shard`` can generate exactly the (lat, lon, channel) slice a rank
owns -- and a property test asserts shard == full[slice] bit-for-bit.

The "forecast" target is the same field advanced by one phase step
(advection + mild nonlinearity), so models genuinely learn dynamics and
training losses are meaningful.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class WeatherDataConfig:
    lat: int
    lon: int
    channels: int
    n_modes: int = 8
    seed: int = 0
    dt_phase: float = 0.35          # time-step phase advance (the "6h")
    noise: float = 0.02


class WeatherDataset:
    def __init__(self, cfg: WeatherDataConfig):
        self.cfg = cfg

    # -- deterministic per-sample mode coefficients ---------------------
    def _coeffs(self, sample_idx: np.ndarray):
        """amplitudes/frequencies/phases: [B, C, M] each."""
        c = self.cfg
        b = sample_idx.shape[0]
        rngs = [np.random.default_rng(
            np.random.SeedSequence([c.seed, int(s)])) for s in sample_idx]
        amp = np.stack([r.normal(0, 1, (c.channels, c.n_modes)) for r in rngs])
        fla = np.stack([r.integers(1, 5, (c.channels, c.n_modes))
                        for r in rngs]).astype(np.float64)
        flo = np.stack([r.integers(1, 7, (c.channels, c.n_modes))
                        for r in rngs]).astype(np.float64)
        phs = np.stack([r.uniform(0, 2 * np.pi, (c.channels, c.n_modes))
                        for r in rngs])
        return amp, fla, flo, phs

    def _eval(self, sample_idx, lat_ix, lon_ix, chan_ix, t: float
              ) -> np.ndarray:
        """Evaluate fields at time offset t on an index sub-grid.
        Returns [B, len(lat_ix), len(lon_ix), len(chan_ix)] float32."""
        c = self.cfg
        amp, fla, flo, phs = self._coeffs(sample_idx)
        amp, fla, flo, phs = (a[:, chan_ix] for a in (amp, fla, flo, phs))
        la = 2 * np.pi * lat_ix[None, :] / c.lat      # [1, La]
        lo = 2 * np.pi * lon_ix[None, :] / c.lon      # [1, Lo]
        # field = sum_m amp * sin(f_la*la + f_lo*lo + phase + t)
        #   evaluated separably: sin(A+B) = sinA cosB + cosA sinB
        arg_lat = fla[:, :, :, None] * la[None, None]     # [B, C, M, La]
        arg_lon = (flo[:, :, :, None] * lo[None, None]
                   + phs[:, :, :, None] + t)              # [B, C, M, Lo]
        s = (np.sin(arg_lat)[:, :, :, :, None]
             * np.cos(arg_lon)[:, :, :, None, :]
             + np.cos(arg_lat)[:, :, :, :, None]
             * np.sin(arg_lon)[:, :, :, None, :])         # [B, C, M, La, Lo]
        f = np.einsum("bcm,bcmxy->bxyc", amp, s) / np.sqrt(c.n_modes)
        # mild nonlinearity so the map is not purely linear
        f = f + 0.1 * f ** 2
        return f.astype(np.float32)

    # -- public API ------------------------------------------------------
    def sample_batch(self, step: int, batch_size: int,
                     horizon: int = 1) -> dict:
        """``horizon``: number of dt steps between input and target (the
        rollout fine-tuning target is the state ``horizon`` steps ahead,
        paper §6)."""
        idx = np.arange(batch_size, dtype=np.int64) + step * batch_size
        lat = np.arange(self.cfg.lat)
        lon = np.arange(self.cfg.lon)
        ch = np.arange(self.cfg.channels)
        x = self._eval(idx, lat, lon, ch, 0.0)
        y = self._eval(idx, lat, lon, ch, horizon * self.cfg.dt_phase)
        if self.cfg.noise:
            r = np.random.default_rng(
                np.random.SeedSequence([self.cfg.seed, 999, step]))
            y = y + self.cfg.noise * r.normal(size=y.shape).astype(np.float32)
        return {"fields": x, "target": y}

    def sample_shard(self, step: int, batch_size: int,
                     lon_slice: slice = slice(None),
                     chan_slice: slice = slice(None),
                     row_slice: slice = slice(None),
                     lat_slice: slice = slice(None),
                     horizon: int = 1) -> dict:
        """Domain-parallel read: only the (lon, channel) partition this
        model-parallel rank owns (paper §5 "Data loading"), and only the
        ``row_slice`` rows of the global batch this data-parallel rank
        owns.  Identical to slicing ``sample_batch(..., horizon=horizon)``
        (property-tested), but touches only the sliced portion of the
        grid.  ``horizon`` must match ``sample_batch``'s for rollout
        fine-tuning targets to agree."""
        idx = (np.arange(batch_size, dtype=np.int64)
               + step * batch_size)[row_slice]
        lat = np.arange(self.cfg.lat)[lat_slice]
        lon = np.arange(self.cfg.lon)[lon_slice]
        ch = np.arange(self.cfg.channels)[chan_slice]
        x = self._eval(idx, lat, lon, ch, 0.0)
        y = self._eval(idx, lat, lon, ch, horizon * self.cfg.dt_phase)
        if self.cfg.noise:
            # noise is per-full-grid; regenerate and slice for consistency
            r = np.random.default_rng(
                np.random.SeedSequence([self.cfg.seed, 999, step]))
            full = self.cfg
            n = r.normal(size=(batch_size, full.lat, full.lon,
                               full.channels)).astype(np.float32)
            y = y + self.cfg.noise * n[row_slice][:, lat_slice][
                :, :, lon_slice, chan_slice]
        return {"fields": x, "target": y}

    def io_bytes_per_rank(self, batch_size: int, n_ranks: int) -> int:
        """Modeled I/O volume per rank per step (for the Fig-7 roofline's
        I/O-bandwidth-limited regime): domain parallelism divides the
        sample bytes by the number of model-parallel ranks."""
        c = self.cfg
        return 4 * batch_size * c.lat * c.lon * c.channels // n_ranks
