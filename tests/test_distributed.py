"""Distributed Jigsaw correctness, run in subprocesses (each with 16
host-emulated devices so XLA_FLAGS never leaks into other tests)."""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SCRIPT = os.path.join(HERE, "dist_scenarios.py")

SCENARIOS = [
    "jigsaw_1d",
    "jigsaw_1d_fsdp",
    "jigsaw_2d",
    # ring_chunked_parity runs via tests/test_kernel_parity.py (the
    # kernels CI job needs it there; listing it here too would double
    # its interpret-mode cost in tier-1)
    "ring_collectives",
    "weathermixer_schemes",
    "transformer_1d",
    "train_step_mesh",
    "input_pipeline",
    "engine_pipeline",
    "zero1_engine",
    # ckpt_sharded_reshard runs via tests/test_checkpoint.py (the
    # checkpoint CI job needs it there; listing it here too would
    # double its cost in tier-1)
    "resume_exact",
    "precision_bf16",
    # preempt_resume_exact + elastic_reshard_resume run via
    # tests/test_resilience.py (the resilience CI job needs them there;
    # listing them here too would double their cost in tier-1)
    # serving_restore runs via tests/test_serve.py (the serve CI job
    # needs it there; same double-cost rule)
]


@pytest.mark.parametrize("scenario", SCENARIOS)
def test_scenario(scenario):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    env.pop("JAX_PLATFORMS", None)
    res = subprocess.run(
        [sys.executable, SCRIPT, scenario], env=env,
        capture_output=True, text=True, timeout=600)
    assert res.returncode == 0 and "ALL-OK" in res.stdout, (
        f"\nstdout:\n{res.stdout[-3000:]}\nstderr:\n{res.stderr[-3000:]}")
