"""Sharding-spec derivation unit tests (launch/specs.py)."""
import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.sharding import RULES_1D, RULES_2D
from repro.launch import specs as S


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


MESH = FakeMesh({"data": 16, "model": 16})


def test_sanitize_spec_drops_non_dividing():
    m = jax.make_mesh((1,), ("model",))  # real mesh for shape lookup
    # use a dict-mesh stand-in via the FakeMesh duck type
    out = S.sanitize_spec((8, 128), P("model", None), MESH)
    assert out == P(None, None)          # 8 % 16 != 0 -> replicate
    out = S.sanitize_spec((32, 128), P("model", "data"), MESH)
    assert out == P("model", "data")
    out = S.sanitize_spec((1,), P(("data", "model")), MESH)
    assert out == P(None)


def test_zero1_adds_data_axis():
    pspecs = {"w": P(None, "model"), "b": P("model"),
              "scale": P(None)}
    ospecs = S.opt_specs(None, pspecs, zero1_axis="data")
    assert ospecs["mu"]["w"] == P("data", "model")
    assert ospecs["nu"]["scale"] == P("data")
    # never doubles an axis already in use
    pspecs2 = {"w": P("data", "model")}
    o2 = S.opt_specs(None, pspecs2, zero1_axis="data")
    assert o2["mu"]["w"] == P("data", "model")


def test_zero1_shape_aware_skips_non_dividing_dims():
    """With moments + mesh, z1 skips dims the data extent can't divide:
    a stacked [n_layers, m, d] leaf shards its m dim, not the tiny layer
    dim (which sanitize_tree would only drop again)."""
    import jax.numpy as jnp
    moments = {"w": jnp.zeros((2, 128, 64)), "b": jnp.zeros((2, 64))}
    pspecs = {"w": P(None, None, "model"), "b": P(None, "model")}
    ospecs = S.opt_specs(moments, pspecs, zero1_axis="data", mesh=MESH)
    assert ospecs["mu"]["w"] == P(None, "data", "model")   # 2 % 16 != 0
    assert ospecs["mu"]["b"] == P(None, "model")           # nothing divides
    # short specs are padded to the leaf rank before the scan
    moments2 = {"w": jnp.zeros((2, 32))}
    o2 = S.opt_specs(moments2, {"w": P()}, zero1_axis="data", mesh=MESH)
    assert o2["mu"]["w"] == P(None, "data")


def test_lm_head_and_table_shard_vocab_dim():
    import jax.numpy as jnp
    params = {"lm_head": {"w": jnp.zeros((1024, 64))},
              "embed": {"table": jnp.zeros((1024, 64))},
              "layer": {"ffn": {"w": jnp.zeros((256, 64))}}}
    from repro.configs.registry import get_config
    cfg = get_config("internlm2-1.8b").reduced()
    specs = S.param_specs(params, cfg, RULES_1D, MESH)
    assert specs["lm_head"]["w"] == P("model", None)   # vocab dim
    assert specs["embed"]["table"] == P("model", None)
    assert specs["layer"]["ffn"]["w"] == P(None, "model")  # contracting


def test_kv_cache_spec_modes():
    import jax.numpy as jnp
    from repro.configs.registry import get_config
    cache = {"k": jnp.zeros((4, 8, 64, 8, 16)), "pos": jnp.zeros((8,))}
    cfg = get_config("dbrx-132b")          # kv=8, uneven over 16
    specs = S.cache_specs(cache, cfg, RULES_1D, MESH)
    assert specs["k"] == P(None, ("data",), "model", None, None)  # seq mode
    cfg16 = get_config("gemma3-27b")       # kv=16, even
    specs = S.cache_specs(cache, cfg16, RULES_1D, MESH)
    assert specs["k"] == P(None, ("data",), None, "model", None)  # heads
