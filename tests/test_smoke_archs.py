"""Per-architecture smoke tests (assigned deliverable f): REDUCED variant
of each family (2 layers, d_model<=512, <=4 experts) runs one forward and
one train step on CPU; output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config
from repro.launch import shapes as SH
from repro.models import registry as M
from repro.optim import adam
from repro.train.step import make_train_step

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def make_batch(cfg, with_labels=True):
    if cfg.family == "mixer":
        f = jax.random.normal(KEY, (B, cfg.wm_lat, cfg.wm_lon,
                                    cfg.wm_channels))
        return {"fields": f, "target": f * 0.9}
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    if with_labels:
        batch["labels"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        batch["embeds"] = jax.random.normal(KEY, (B, cfg.n_patches,
                                                  cfg.d_model))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(KEY, (B, cfg.n_frames,
                                                  cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nan(arch):
    cfg = get_config(arch).reduced()
    params = M.init(KEY, cfg)
    batch = make_batch(cfg, with_labels=False)
    out, aux = M.apply(params, batch, cfg, SH.jigsaw_for(cfg))
    if cfg.family == "mixer":
        assert out.shape == batch["fields"].shape
    else:
        exp_s = S + (cfg.n_patches if cfg.family == "vlm" else 0)
        assert out.shape == (B, exp_s, cfg.vocab_padded)
    assert not bool(jnp.any(jnp.isnan(out))), f"{arch}: NaNs in forward"
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    params = M.init(KEY, cfg)
    acfg = adam.AdamConfig()
    opt = adam.init(params, acfg)
    step = make_train_step(cfg, SH.jigsaw_for(cfg), acfg)
    batch = make_batch(cfg)
    new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: non-finite loss"
    assert int(new_opt["step"]) == 1
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32),
                        np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(new_params)))
    assert moved, f"{arch}: train step did not update params"


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_config(a).supports_decode])
def test_decode_step_shapes(arch):
    cfg = get_config(arch).reduced()
    params = M.init(KEY, cfg)
    cache = M.init_cache(cfg, B, 64, dtype=jnp.float32)
    tokens = jax.random.randint(KEY, (B, 1), 0, cfg.vocab_size)
    logits, new_cache = M.decode_step(params, cache, tokens, cfg,
                                      SH.jigsaw_for(cfg))
    assert logits.shape == (B, 1, cfg.vocab_padded)
    assert not bool(jnp.any(jnp.isnan(logits))), f"{arch}: NaNs in decode"
    assert int(new_cache["pos"][0]) == 1


def test_param_counts_match_analytic():
    """Analytic param_count (used for MODEL_FLOPS) matches real init."""
    for arch in ARCH_IDS:
        cfg = get_config(arch).reduced()
        params = M.init(KEY, cfg)
        real = sum(x.size for x in jax.tree.leaves(params))
        analytic = cfg.param_count()
        assert abs(real - analytic) / real < 0.02, (
            f"{arch}: analytic {analytic} vs real {real}")
