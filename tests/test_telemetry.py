"""Telemetry subsystem tests (DESIGN.md §14): tracer semantics (span
nesting/ordering under threads, Chrome trace-event schema, counter and
gauge behavior under contention), exporter flush on preemption, and the
MFU / comm-fraction accounting pinned against the Fig. 7 roofline
numbers for weathermixer-1b."""
import json
import math
import os
import subprocess
import sys
import threading

import pytest

from repro import telemetry
from repro.configs.registry import get_config
from repro.launch import analysis as A
from repro.launch import trace_report
from repro.telemetry.spans import Tracer


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

def _xs(tr, name=None):
    evs = [e for e in tr.chrome_events() if e.get("ph") == "X"]
    return [e for e in evs if name is None or e["name"] == name]


def test_span_nesting_single_thread():
    tr = Tracer()
    with tr.span("outer", step=0):
        with tr.span("inner_a"):
            pass
        with tr.span("inner_b"):
            pass
    outer, = _xs(tr, "outer")
    for inner in _xs(tr, "inner_a") + _xs(tr, "inner_b"):
        assert outer["ts"] <= inner["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
        assert inner["tid"] == outer["tid"]
    a, = _xs(tr, "inner_a")
    b, = _xs(tr, "inner_b")
    assert a["ts"] + a["dur"] <= b["ts"]          # sequenced, not nested
    assert outer["args"] == {"step": 0}


def test_span_dur_s_readable_after_exit():
    tr = Tracer()
    with tr.span("work") as sp:
        pass
    assert sp.dur_s >= 0.0 and sp.dur_ns >= 0


def test_span_tracks_per_thread():
    tr = Tracer()
    barrier = threading.Barrier(3)

    def worker(tag):
        barrier.wait()
        for i in range(5):
            with tr.span("w", tag=tag, i=i):
                with tr.span("w.child", tag=tag):
                    pass

    ts = [threading.Thread(target=worker, args=(k,), name=f"th-{k}")
          for k in range(2)]
    for t in ts:
        t.start()
    barrier.wait()
    with tr.span("main"):
        pass
    for t in ts:
        t.join()

    spans = _xs(tr, "w")
    tids = {e["tid"] for e in spans}
    assert len(spans) == 10 and len(tids) == 2
    # every child is contained in a parent ON ITS OWN TRACK
    for ch in _xs(tr, "w.child"):
        assert any(p["tid"] == ch["tid"] and p["ts"] <= ch["ts"]
                   and ch["ts"] + ch["dur"] <= p["ts"] + p["dur"]
                   for p in spans)
    # thread-name metadata covers every track
    meta = {e["tid"]: e["args"]["name"]
            for e in tr.chrome_events() if e.get("ph") == "M"
            and e["name"] == "thread_name"}
    for tid in tids:
        assert meta[tid].startswith("th-")


def test_disabled_tracer_records_no_events_but_counts():
    tr = Tracer(enabled=False)
    with tr.span("invisible") as sp:
        pass
    assert sp.dur_s == 0.0                 # the shared null span
    tr.event("also_invisible")
    assert _xs(tr) == []
    assert tr.counter("c", 2) == 2.0       # counters stay live
    tr.gauge("g", 7)
    tr.observe("h", 0.5)
    assert tr.counters()["c"] == 2.0
    assert tr.gauges()["g"] == 7
    assert tr.hist_summary("h")["count"] == 1


def test_ring_buffer_bounds_events():
    tr = Tracer(ring=10)
    for i in range(50):
        with tr.span("s", i=i):
            pass
    spans = _xs(tr, "s")
    assert len(spans) == 10
    assert [e["args"]["i"] for e in spans] == list(range(40, 50))


# ---------------------------------------------------------------------------
# Chrome trace schema
# ---------------------------------------------------------------------------

def test_chrome_export_schema(tmp_path):
    tr = Tracer()
    with tr.span("step", step=0):
        with tr.span("dispatch"):
            pass
    tr.event("preempt.signal", signum=15)
    tr.gauge("pipeline.queue_depth", 2)
    path = str(tmp_path / "out.trace.json")
    tr.export_chrome(path)
    with open(path) as f:
        doc = json.load(f)
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    evs = doc["traceEvents"]
    assert all({"name", "ph", "pid", "tid"} <= set(e) for e in evs)
    phases = {e["ph"] for e in evs}
    assert {"M", "X", "i", "C"} <= phases
    for e in evs:
        if e["ph"] == "X":
            assert isinstance(e["ts"], float) and isinstance(e["dur"],
                                                             float)
            assert e["ts"] >= 0 and e["dur"] >= 0
        if e["ph"] == "i":
            assert e["s"] == "t"
        if e["ph"] == "C":
            assert e["args"]           # the plotted value
    names = {e["name"] for e in evs}
    assert {"process_name", "thread_name", "step", "dispatch",
            "preempt.signal", "pipeline.queue_depth"} <= names


def test_jsonl_export_roundtrip(tmp_path):
    tr = Tracer()
    tr.set_meta(arch="x", mesh_model=2)
    tr.step_record(step=0, dur_s=0.5, mfu=0.5, comm_fraction=0.1,
                   achieved_tflops=10.0)
    with tr.span("step", step=0):
        pass
    tr.counter("c")
    tr.observe("h", 1.0)
    path = str(tmp_path / "out.trace.jsonl")
    tr.export_jsonl(path)
    with open(path) as f:
        recs = [json.loads(line) for line in f]
    kinds = [r["kind"] for r in recs]
    assert kinds[0] == "meta" and recs[0]["arch"] == "x"
    assert {"step", "spans", "counters", "gauges",
            "histogram"} <= set(kinds)
    meta, steps, spans, counters, _, hists = \
        trace_report.split_records(recs)
    assert meta["mesh_model"] == 2 and len(steps) == 1
    assert spans["step"]["count"] == 1
    assert counters["c"] == 1 and hists[0]["name"] == "h"
    assert trace_report.check(meta, steps) == []


# ---------------------------------------------------------------------------
# counters / gauges / histograms
# ---------------------------------------------------------------------------

def test_counter_monotonic_and_exact_under_threads():
    tr = Tracer(enabled=False)
    per, threads = 500, 8

    def worker():
        prev = -1.0
        for _ in range(per):
            v = tr.counter("hits")
            assert v > prev              # monotonic as observed here
            prev = v
        tr.add_counters({"bytes": 10, "batches": 1})

    ts = [threading.Thread(target=worker) for _ in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    got = tr.counters()
    assert got["hits"] == per * threads   # no lost read-modify-writes
    assert got["bytes"] == 10 * threads
    assert got["batches"] == threads


def test_gauge_is_last_value():
    tr = Tracer()
    for v in (3, 1, 7):
        tr.gauge("depth", v)
    assert tr.gauges()["depth"] == 7
    # each update is also a plotted Chrome "C" sample
    cs = [e for e in tr.chrome_events() if e.get("ph") == "C"]
    assert [e["args"]["value"] for e in cs] == [3, 1, 7]


def test_histogram_percentiles():
    tr = Tracer()
    for v in range(1, 101):
        tr.observe("lat", float(v))
    s = tr.hist_summary("lat")
    assert s["count"] == 100 and s["min"] == 1 and s["max"] == 100
    assert s["p50"] == 51 and s["p99"] == 100
    assert tr.percentile("lat", 0.95) == 96
    assert math.isnan(tr.percentile("nope", 0.5))
    assert tr.hist_summary("nope") == {"count": 0}


def test_pipeline_stats_batch_is_atomic():
    """The satellite fix: PipelineStats updates ride the tracer lock as
    one critical section per batch -- hammer it from threads and the
    totals are exact."""
    from repro.data.pipeline import PipelineStats
    st = PipelineStats()
    n, per = 6, 200

    def worker(k):
        for i in range(per):
            st.record_batch([("fields", k, 100, True),
                             ("fields", 1000 + k, 100, False)], steps=1)

    ts = [threading.Thread(target=worker, args=(k,)) for k in range(n)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert st.steps == n * per
    assert st.generated_bytes["fields"] == 100 * n * per
    assert sum(st.rank_bytes["fields"].values()) == 2 * 100 * n * per


# ---------------------------------------------------------------------------
# MFU / comm-fraction accounting, pinned against Fig. 7
# ---------------------------------------------------------------------------

WM = "weathermixer-1b"


def test_fig7_point_pinned():
    """``fig7_point`` must reproduce benchmarks/fig7_roofline.py's
    wm-1b rows bit-for-bit; these constants are PINNED -- if they move,
    the roofline model changed and EXPERIMENTS.md is stale."""
    cfg = get_config(WM)
    p1 = telemetry.fig7_point(cfg, 1)
    assert p1["peak_frac"] == pytest.approx(1.0)
    assert p1["t_coll_s"] == 0.0
    p2 = telemetry.fig7_point(cfg, 2)
    assert p2["peak_frac"] == pytest.approx(0.785543, rel=1e-4)
    assert p2["tflops_per_dev"] == pytest.approx(154.752, rel=1e-4)
    assert p2["regime"] == "compute-comm"
    p4 = telemetry.fig7_point(cfg, 4)
    assert p4["peak_frac"] == pytest.approx(0.646827, rel=1e-4)
    # chunked overlap hides the 2-way ring entirely behind compute
    pc = telemetry.fig7_point(cfg, 2, impl="ring_chunked")
    assert pc["peak_frac"] == pytest.approx(1.0)
    # scaling sanity: wider jigsaw -> smaller per-device step time
    assert p4["t_step_s"] < p2["t_step_s"] < p1["t_step_s"]


def test_cost_model_mfu_8way():
    """wm-1b on an 8-way model mesh: the accounting identities the step
    records are built from."""
    cfg = get_config(WM)
    cm = telemetry.build_cost_model(cfg, n_model=8, n_data=1, batch=1)
    assert cm.n_devices == 8 and cm.flops_per_step > 0
    assert cm.comm_bytes_per_device > 0 and cm.hops == 7
    # a step that runs exactly at the compute roofline is MFU 1.0 at
    # peak TFLOPs by construction
    m = cm.metrics(cm.t_compute_s)
    assert m["mfu"] == pytest.approx(1.0)
    assert m["achieved_tflops"] == pytest.approx(A.PEAK_FLOPS_BF16 / 1e12)
    # twice the time -> half the MFU; rollout r scales work r-fold
    assert cm.metrics(2 * cm.t_compute_s)["mfu"] == pytest.approx(0.5)
    assert cm.metrics(2 * cm.t_compute_s, rollout=2)["mfu"] == \
        pytest.approx(1.0)
    # comm_fraction is the modeled collective share, capped at 1
    t = 10 * cm.t_collective_s
    assert cm.metrics(t)["comm_fraction"] == pytest.approx(0.1)
    assert cm.metrics(0.5 * cm.t_collective_s)["comm_fraction"] == 1.0
    # degenerate timings stay finite
    z = cm.metrics(0.0)
    assert z == {"mfu": 0.0, "achieved_tflops": 0.0, "comm_fraction": 0.0}


def test_cost_model_comm_matches_fig7_collective_term():
    """The cost model's per-device collective seconds at batch=1 equal
    the Fig. 7 t_coll for the same (config, way) -- same formula, same
    constants, independently arrived at."""
    cfg = get_config(WM).replace(scheme="1d")
    cm = telemetry.build_cost_model(cfg, n_model=2, n_data=1, batch=1)
    p2 = telemetry.fig7_point(cfg, 2)
    assert cm.t_collective_s == pytest.approx(p2["t_coll_s"], rel=1e-12)


def test_cost_model_meta_roundtrips_through_report():
    cfg = get_config(WM).reduced()
    cm = telemetry.build_cost_model(cfg, n_model=4, n_data=2, batch=8)
    tr = Tracer()
    tr.set_meta(arch=WM, cost_model=cm.as_meta())
    for i in range(3):
        tr.step_record(step=i, rollout=1, dur_s=0.01, data_wait_s=0.001,
                       **cm.metrics(0.01))
    meta, steps, *_ = trace_report.split_records(tr.jsonl_records())
    assert trace_report.check(meta, steps) == []
    att = trace_report.attribution(meta, steps)
    assert att is not None
    assert att["data"] == pytest.approx(0.1, rel=1e-6)
    total = att["data"] + att["compute"] + att["collective"] + att["other"]
    assert 0.0 < total <= 3.0 + 1e-9       # shares are clamped per-term
    assert "bound" in trace_report.verdict(att)


def test_trace_report_check_catches_bad_records():
    assert trace_report.check({}, []) == [
        "no meta header record", "no step records"]
    bad = [{"step": 0, "dur_s": 0.1, "mfu": float("nan"),
            "comm_fraction": 0.2, "achieved_tflops": 1.0}]
    fails = trace_report.check({"arch": "x"}, bad)
    assert any("mfu" in f and "not finite" in f for f in fails)
    bad2 = [{"step": 1, "dur_s": 0.1, "mfu": 1.5, "comm_fraction": 0.2,
             "achieved_tflops": 1.0}]
    assert any("outside" in f
               for f in trace_report.check({"arch": "x"}, bad2))


# ---------------------------------------------------------------------------
# engine integration: exporter flush on Preempted
# ---------------------------------------------------------------------------

def test_trace_flushed_on_preempted(tmp_path):
    """A preempted run must leave a complete, loadable trace behind --
    the moment the operator most needs it."""
    from repro.launch import resilience
    from repro.launch.engine import EngineConfig, TrainEngine

    trace = str(tmp_path / "run.trace.json")
    eng = TrainEngine(
        "internlm2-1.8b",
        config=EngineConfig(steps=4, batch=2, seq_len=16, log_every=1,
                            ckpt=str(tmp_path / "ck"), trace=trace,
                            preempt_at_step=1))
    with pytest.raises(resilience.Preempted):
        eng.run()

    with open(trace) as f:
        doc = json.load(f)
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"step", "dispatch", "data_wait", "preempt.chaos_sigterm",
            "preempt.signal", "preempt.final_save"} <= names

    meta, steps, *_ = trace_report.split_records(
        trace_report.load_records(telemetry.jsonl_path_for(trace)))
    assert [s["step"] for s in steps] == [0, 1]   # flushed through i=1
    assert trace_report.check(meta, steps) == []
    assert meta["arch"] == "internlm2-1.8b"
    assert meta["cost_model"]["flops_per_step"] > 0


def test_metrics_json_compat_mode(tmp_path):
    """--metrics-format json keeps the legacy whole-file dump (written
    once, at run end -- not O(n^2) re-dumped every flush)."""
    from repro.launch.engine import EngineConfig, TrainEngine

    mfile = str(tmp_path / "m.json")
    eng = TrainEngine(
        "internlm2-1.8b",
        config=EngineConfig(steps=3, batch=2, seq_len=16, log_every=1,
                            metrics_out=mfile, metrics_format="json",
                            telemetry=False))
    hist = eng.run()
    with open(mfile) as f:
        logged = json.load(f)                      # one JSON document
    assert [h["step"] for h in logged] == [h["step"] for h in hist]

    with pytest.raises(ValueError):
        TrainEngine("internlm2-1.8b",
                    config=EngineConfig(steps=1, metrics_format="csv"))


def test_serve_engine_latency_histograms():
    """ForecastEngine.summary percentiles come from its telemetry
    histograms, per lead time."""
    from repro.serve.engine import ForecastEngine, ServeConfig

    eng = ForecastEngine(WM, config=ServeConfig(buckets=(2,)))
    import numpy as np
    fields = np.zeros(eng.field_shape, np.float32)
    rs = [eng.submit(fields, lead) for lead in (1, 2, 2)]
    eng.drain()
    assert all(r.done() for r in rs)
    s = eng.summary(rs)
    assert s["deliveries"] == 3
    assert math.isfinite(s["p50_s"]) and math.isfinite(s["p99_s"])
    assert set(s["lead_latency_s"]) == {1, 2}
    assert s["lead_latency_s"][2]["count"] == 2
    # longer leads take more rollout steps -> no smaller latency
    assert s["lead_latency_s"][2]["p50"] >= \
        s["lead_latency_s"][1]["p50"] - 1e-9
    names = {e["name"] for e in eng.tracer.chrome_events()}
    assert {"serve.step", "serve.peel"} <= names


def test_telemetry_trace_scenario():
    """The end-to-end acceptance run (subprocess, 16 emulated devices):
    an instrumented 4x2 wm-1b training run produces a Perfetto-valid
    Chrome trace with nested data-wait/step/ckpt spans, a JSONL whose
    mfu/comm_fraction match the analytic model within ±5%, and an HLO
    collective-byte cross-check of the wire model."""
    here = os.path.dirname(__file__)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    env.pop("JAX_PLATFORMS", None)
    res = subprocess.run(
        [sys.executable, os.path.join(here, "dist_scenarios.py"),
         "telemetry_trace"],
        env=env, capture_output=True, text=True, timeout=900)
    assert res.returncode == 0 and "ALL-OK" in res.stdout, (
        f"\nstdout:\n{res.stdout[-3000:]}\nstderr:\n{res.stderr[-3000:]}")
