"""Decode-path integration tests: token-by-token decode reproduces the
teacher-forced forward logits for every decodable architecture family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.launch import shapes as SH
from repro.models import registry as M

KEY = jax.random.PRNGKey(0)

# one representative per decode-relevant family/pattern
ARCHS = ["internlm2-1.8b",        # dense GQA
         "h2o-danube-1.8b",       # sliding window (rolling cache)
         "gemma3-27b",            # local:global period cache
         "mamba2-130m",           # SSM state
         "jamba-1.5-large-398b",  # hybrid period cache
         "whisper-small"]         # enc-dec with cross-attention


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_teacher_forced(arch):
    cfg = get_config(arch).reduced()
    if cfg.n_experts:
        # ample capacity: token dropping is load-dependent and would make
        # teacher-forced vs decode legitimately diverge
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts))
    jcfg = SH.jigsaw_for(cfg)
    params = M.init(KEY, cfg)
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens}
    extra = {}
    if cfg.family == "audio":
        frames = jax.random.normal(jax.random.PRNGKey(2),
                                   (B, cfg.n_frames, cfg.d_model))
        batch["frames"] = frames
        extra["frames"] = frames
    ref_logits, _ = M.apply(params, batch, cfg, jcfg)

    cache = M.init_cache(cfg, B, S + 2, dtype=jnp.float32)
    if cfg.family == "audio":
        from repro.models import encdec
        cache["enc"] = encdec.encode(params, frames, cfg, jcfg).astype(
            cache["enc"].dtype)
    got = []
    for t in range(S):
        logits, cache = M.decode_step(params, cache, tokens[:, t:t + 1],
                                      cfg, jcfg)
        got.append(logits[:, 0])
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref_logits),
                               rtol=5e-3, atol=5e-3)


def test_generate_runs():
    from repro.serve.step import generate
    cfg = get_config("stablelm-3b").reduced()
    params = M.init(KEY, cfg)
    prompts = jax.random.randint(KEY, (2, 4), 0, cfg.vocab_size)
    out = generate(params, prompts, cfg, SH.jigsaw_for(cfg), steps=5,
                   max_len=16)
    assert out.shape == (2, 5)
    assert int(out.max()) < cfg.vocab_size
