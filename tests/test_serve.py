"""Serving subsystem tests (ISSUE 8).

* scheduler policy units (fake clock, no devices): coalescing window,
  step-boundary admission, drain vs continuous, bucket growth, lead
  fan-out ordering;
* ForecastEngine on one device: batch-bucket compile-cache hits
  (trace-time compile counter), mid-rollout admission correctness
  (outputs bitwise equal solo rollouts), continuous < drain step
  counts;
* serve/step satellites: fused prefill parity vs the token-wise
  reference, donated decode cache (buffers actually deleted), no
  per-step device->host round-trips, jit-cache reuse across generate
  calls;
* read-only serving restore: arch validation + precision cast;
* the 8-way-ckpt -> {1,2,4,8}-way serving-mesh bit-identity scenario
  (subprocess with 16 emulated devices; also the serve CI job).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.launch import shapes as SH
from repro.models import registry as M
from repro.serve import step as SS
from repro.serve.engine import ForecastEngine, ServeConfig
from repro.serve.scheduler import ForecastResult, MicrobatchScheduler

HERE = os.path.dirname(__file__)


# ---------------------------------------------------------------------------
# scheduler policy (host-only, fake clock)
# ---------------------------------------------------------------------------

class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _req(clock, leads=(1,)):
    return ForecastResult(None, tuple(sorted(leads)), submit_t=clock())


def test_scheduler_coalescing_window():
    clk = FakeClock()
    s = MicrobatchScheduler((1, 2, 4), coalesce_s=0.5, clock=clk)
    s.submit(_req(clk))
    t = s.tick()
    assert t.wait == pytest.approx(0.5) and not t.step
    clk.t = 0.3
    t = s.tick()
    assert t.wait == pytest.approx(0.2) and not t.step
    clk.t = 0.51          # window expired: form the batch
    t = s.tick()
    assert t.form == 1 and len(t.admit) == 1 and t.step
    assert s.counters["waited"] == 2


def test_scheduler_coalescing_full_bucket_bypasses_window():
    clk = FakeClock()
    s = MicrobatchScheduler((1, 2, 4), coalesce_s=10.0, clock=clk)
    for _ in range(4):    # a full max-size bucket never waits
        s.submit(_req(clk))
    t = s.tick()
    assert t.form == 4 and len(t.admit) == 4 and t.step


def test_scheduler_bucket_for():
    s = MicrobatchScheduler((1, 2, 4, 8))
    assert [s.bucket_for(n) for n in (1, 2, 3, 5, 8, 100)] == \
        [1, 2, 4, 8, 8, 8]


def test_scheduler_continuous_admission_at_boundaries():
    clk = FakeClock()
    s = MicrobatchScheduler((1, 2, 4), clock=clk)
    s.submit(_req(clk, (3,)))
    t = s.tick()
    assert t.form == 1 and len(t.admit) == 1
    s.advance()
    # a new request arrives mid-rollout: admitted at the NEXT boundary,
    # growing the live batch one bucket hop
    s.submit(_req(clk, (1,)))
    t = s.tick()
    assert t.grow == 2 and len(t.admit) == 1 and t.step
    peels, finished = s.advance()     # ages: 2 and 1
    assert [lead for _, _, lead in peels] == [1]
    assert len(finished) == 1 and s.active() == 1
    t = s.tick()                      # freed slot, empty queue: just step
    assert t.grow is None and not t.admit and t.step
    s.advance()                       # first request hits lead 3
    assert s.active() == 0


def test_scheduler_drain_mode_no_midflight_admission():
    clk = FakeClock()
    s = MicrobatchScheduler((1, 2, 4), mode="drain", clock=clk)
    s.submit(_req(clk, (2,)))
    assert s.tick().form == 1
    s.advance()
    s.submit(_req(clk, (1,)))
    t = s.tick()                      # drain: queued request NOT admitted
    assert not t.admit and t.grow is None and t.step
    s.advance()                       # batch empties
    t = s.tick()                      # only now the next batch forms
    assert t.form == 1 and len(t.admit) == 1


def test_scheduler_fanout_ordering():
    clk = FakeClock()
    s = MicrobatchScheduler((4,), clock=clk)
    r = _req(clk, (2, 1, 5))          # unsorted on purpose
    assert r.leads == (1, 2, 5)
    s.submit(r)
    s.tick()
    seen = []
    for _ in range(5):
        peels, _ = s.advance()
        seen += [lead for _, req, lead in peels if req is r]
        s.tick()
    assert seen == [1, 2, 5]          # peeled in rollout order
    assert s.counters["completed"] == 1


# ---------------------------------------------------------------------------
# ForecastEngine (single device, tiny mixer)
# ---------------------------------------------------------------------------

def tiny_engine(**kw):
    cfg = get_config("weathermixer-1b").reduced().replace(
        wm_lat=16, wm_lon=32, wm_channels=4, d_model=64,
        wm_d_tok=64, wm_d_ch=64)
    config = kw.pop("config", ServeConfig(buckets=(1, 2, 4)))
    return ForecastEngine("weathermixer-1b", reduced=False,
                          config_override=cfg, config=config, **kw)


def _fields(n, eng, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, *eng.field_shape)).astype(np.float32)


def test_engine_zero_recompiles_across_buckets():
    eng = tiny_engine()
    warm = eng.warmup()               # 3 buckets x 4 fns + 2 grows
    assert warm == 14
    assert eng.compile_cache_size() == warm
    fs = _fields(7, eng)
    rs = [eng.submit(fs[i], (i % 3) + 1) for i in range(7)]
    eng.drain()
    assert all(r.done() for r in rs)
    # the load exercised forms, admissions, steps and peels across
    # multiple buckets -- with ZERO new traces or executables
    assert eng.stats["compiles"] == warm
    assert eng.compile_cache_size() == warm
    assert eng.sched.counters["formed"] >= 1


def test_engine_midflight_admission_bitwise_vs_solo():
    eng = tiny_engine()
    eng.warmup()
    fs = _fields(5, eng, seed=1)
    first = eng.submit(fs[0], 4)
    assert eng.step_once() == "step"  # first request in flight...
    late = [eng.submit(fs[i], i) for i in (1, 2, 3)]
    eng.drain()                       # ...the rest admitted mid-rollout
    assert first.done() and all(r.done() for r in late)

    # solo reference: each request alone through the same jitted bucket
    # step (bucket 1) -- continuous batching must not perturb outputs
    def solo(f, lead):
        fns = eng._fns(1)
        state = fns["admit"](fns["zeros"](), eng._put_fields(f),
                             np.int32(0))
        for _ in range(lead):
            state = fns["step"](eng.params, state)
        return np.asarray(fns["peel"](state, np.int32(0)))

    assert np.array_equal(first.result(), solo(fs[0], 4))
    for i, r in zip((1, 2, 3), late):
        assert np.array_equal(r.result(), solo(fs[i], i))


def test_engine_fanout_outputs_and_latency():
    eng = tiny_engine()
    eng.warmup()
    r = eng.submit(_fields(1, eng)[0], (1, 2, 4))
    eng.drain()
    assert sorted(r.outputs) == [1, 2, 4]
    assert r.done() and r.latency() >= 0 and r.queue_delay() >= 0
    # each peeled horizon is a genuine intermediate state of ONE rollout
    fns = eng._fns(1)
    state = fns["admit"](fns["zeros"](), eng._put_fields(r.fields),
                         np.int32(0))
    for lead in (1, 2, 3, 4):
        state = fns["step"](eng.params, state)
        if lead in r.outputs:
            assert np.array_equal(r.output(lead),
                                  np.asarray(state[0]))


def test_engine_continuous_beats_drain_in_steps():
    # mixed leads: drain pays max(lead) per batch, continuous ~mean(lead)
    leads = [1, 4, 1, 4, 1, 4, 1, 4]
    steps = {}
    for mode in ("continuous", "drain"):
        eng = tiny_engine(config=ServeConfig(buckets=(1, 2, 4),
                                             mode=mode))
        eng.warmup()
        fs = _fields(len(leads), eng, seed=2)
        rs = [eng.submit(fs[i], leads[i]) for i in range(len(leads))]
        eng.drain()
        assert all(r.done() for r in rs)
        steps[mode] = eng.stats["device_steps"]
    assert steps["continuous"] < steps["drain"], steps


def test_engine_coalescing_with_fake_clock():
    clk = FakeClock()
    eng = tiny_engine(clock=clk,
                      config=ServeConfig(buckets=(1, 2, 4),
                                         coalesce_s=1.0))
    eng.warmup()
    r1 = eng.submit(_fields(1, eng)[0], 1)
    assert eng.step_once() == "wait"      # window open: no batch yet
    r2 = eng.submit(_fields(1, eng, seed=9)[0], 1)
    clk.t = 1.5
    assert eng.step_once() == "step"      # window closed: ONE batch of 2
    assert r1.done() and r2.done()
    assert eng.sched.counters["formed"] == 1


def test_engine_validation():
    eng = tiny_engine()
    with pytest.raises(ValueError, match="fields shape"):
        eng.submit(np.zeros((3, 3, 3), np.float32), 1)
    with pytest.raises(ValueError, match="leads"):
        eng.submit(np.zeros(eng.field_shape, np.float32), 0)
    with pytest.raises(ValueError, match="family"):
        ForecastEngine("stablelm-3b")
    with pytest.raises(ValueError, match="mode"):
        ServeConfig(mode="nope") and MicrobatchScheduler((1,), mode="nope")


# ---------------------------------------------------------------------------
# serve/step satellites: fused prefill + donated decode
# ---------------------------------------------------------------------------

def _lm(arch="stablelm-3b", **repl):
    cfg = get_config(arch).reduced()
    if repl:
        cfg = cfg.replace(**repl)
    jcfg = SH.jigsaw_for(cfg)
    params = M.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 9)),
                          jnp.int32)
    return cfg, jcfg, params, prompts


@pytest.mark.parametrize("arch", ["stablelm-3b", "h2o-danube-1.8b"])
def test_fused_prefill_parity(arch):
    cfg, jcfg, params, prompts = _lm(arch)
    n_f, c_f = SS.prefill(params, prompts, cfg, jcfg, 24,
                          cache_dtype=jnp.float32, fused=True)
    n_t, c_t = SS.prefill_tokenwise(params, prompts, cfg, jcfg, 24,
                                    cache_dtype=jnp.float32)
    assert np.array_equal(n_f, n_t)
    assert np.array_equal(c_f["pos"], c_t["pos"])
    for k in ("k", "v"):
        assert np.allclose(c_f[k], c_t[k], rtol=5e-3, atol=1e-4)
    g_f = SS.generate(params, prompts, cfg, jcfg, steps=6, max_len=24,
                      fused=True)
    g_t = SS.generate(params, prompts, cfg, jcfg, steps=6, max_len=24,
                      fused=False)
    assert np.array_equal(np.asarray(g_f), np.asarray(g_t))


def test_fused_prefill_rolling_overflow_parity():
    # prompt LONGER than the rolling window: only the last s_max tokens
    # survive, at the same slots token-wise writes would have used
    cfg, jcfg, params, _ = _lm("h2o-danube-1.8b", sliding_window=8)
    rng = np.random.default_rng(1)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 13)),
                          jnp.int32)
    n_f, c_f = SS.prefill(params, prompts, cfg, jcfg, 32,
                          cache_dtype=jnp.float32, fused=True)
    n_t, c_t = SS.prefill_tokenwise(params, prompts, cfg, jcfg, 32,
                                    cache_dtype=jnp.float32)
    assert c_f["k"].shape[2] == 8
    assert np.array_equal(n_f, n_t)
    assert np.allclose(c_f["k"], c_t["k"], rtol=5e-3, atol=1e-4)


def test_fused_prefill_unsupported_family_falls_back():
    cfg, jcfg, params, prompts = _lm("gemma3-27b")   # local:global stack
    assert cfg.local_global_ratio > 0
    with pytest.raises(NotImplementedError):
        SS.prefill(params, prompts, cfg, jcfg, 24, fused=True)
    nxt, cache = SS.prefill(params, prompts, cfg, jcfg, 24)  # auto
    assert nxt.shape == (2, 1) and "lk" in cache


def test_generate_donates_cache_and_stays_on_device():
    cfg, jcfg, params, prompts = _lm()
    _, cache = SS.prefill(params, prompts, cfg, jcfg, 24,
                          cache_dtype=jnp.float32)
    step = SS.jit_serve_step(cfg, jcfg)
    tok = jnp.zeros((2, 1), jnp.int32)
    old_k = cache["k"]
    tok, cache = step(params, cache, tok)     # donation: buffers consumed
    assert old_k.is_deleted()
    # steady-state decode performs no device->host round-trips
    with jax.transfer_guard_device_to_host("disallow"):
        for _ in range(3):
            tok, cache = step(params, cache, tok)
    assert SS.jit_serve_step(cfg, jcfg) is step   # lru-cached wrapper


def test_generate_jit_cache_reused_across_calls():
    cfg, jcfg, params, prompts = _lm()
    SS.generate(params, prompts, cfg, jcfg, steps=4, max_len=24)
    step = SS.jit_serve_step(cfg, jcfg)
    before = step._cache_size()
    SS.generate(params, prompts, cfg, jcfg, steps=4, max_len=24)
    assert step._cache_size() == before       # no re-jit per generate


# ---------------------------------------------------------------------------
# read-only serving restore (single device; mesh reshaping under the
# subprocess scenario below)
# ---------------------------------------------------------------------------

def test_serving_restore_validates_and_casts(tmp_path):
    from functools import partial

    from repro.checkpoint.serving import restore_serving_params
    from repro.core import precision
    from repro.launch.engine import EngineConfig, TrainEngine

    path = str(tmp_path / "ck")
    eng = TrainEngine("weathermixer-1b",
                      config=EngineConfig(steps=2, batch=2, log_every=10))
    eng.run()
    eng.save(path, block=True)

    with pytest.raises(ValueError, match="arch"):
        restore_serving_params(path, arch="stablelm-3b")

    params, man = restore_serving_params(path, arch="weathermixer-1b")
    assert man.step == 2
    # cast-on-restore: a bf16 serving policy gets bf16 leaves from the
    # fp32 checkpoint (the blend stays f32: init keeps it f32 always)
    cfg16 = precision.apply_policy(eng.cfg, "bf16")
    like = jax.eval_shape(partial(M.init, cfg=cfg16), jax.random.PRNGKey(0))
    p16, _ = restore_serving_params(path, like=like)
    assert p16["encoder"]["w"].dtype == jnp.bfloat16
    assert p16["blend"].dtype == jnp.float32
    assert np.allclose(np.asarray(p16["encoder"]["w"], np.float32),
                       params["encoder"]["w"], atol=0.02)

    # shape validation names the offending leaf
    bad = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct((1,) + tuple(l.shape), l.dtype),
        like)
    with pytest.raises(ValueError, match="shape"):
        restore_serving_params(path, like=bad)


def test_engine_serves_checkpoint(tmp_path):
    from repro.launch.engine import EngineConfig, TrainEngine

    path = str(tmp_path / "ck")
    eng = TrainEngine("weathermixer-1b",
                      config=EngineConfig(steps=2, batch=2, log_every=10))
    eng.run()
    eng.save(path, block=True)
    se = ForecastEngine("weathermixer-1b", ckpt=path,
                        config=ServeConfig(buckets=(1, 2)))
    assert se.restored_step == 2
    r = se.submit(np.zeros(se.field_shape, np.float32), 2)
    se.drain()
    assert r.done() and np.isfinite(r.result()).all()


# ---------------------------------------------------------------------------
# 8-way ckpt -> {1,2,4,8}-way serving meshes (subprocess, 16 devices)
# ---------------------------------------------------------------------------

def test_serving_restore_scenario():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    env.pop("JAX_PLATFORMS", None)
    res = subprocess.run(
        [sys.executable, os.path.join(HERE, "dist_scenarios.py"),
         "serving_restore"],
        env=env, capture_output=True, text=True, timeout=600)
    assert res.returncode == 0 and "ALL-OK" in res.stdout, (
        f"\nstdout:\n{res.stdout[-3000:]}\nstderr:\n{res.stderr[-3000:]}")
