"""End-to-end behaviour tests for the full system."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.launch import shapes as SH
from repro.launch.train import train


def test_all_archs_registered():
    assert len(ARCH_IDS) == 11  # 10 assigned + the paper's WeatherMixer
    for a in ARCH_IDS:
        cfg = get_config(a)
        assert cfg.arch_id == a
        assert cfg.source, f"{a}: missing citation"


def test_shape_applicability_matrix():
    """The documented skip matrix (DESIGN.md): exactly the sub-quadratic
    archs run long_500k; mixer skips decode shapes."""
    long_ok = set()
    for a in ARCH_IDS:
        cfg = get_config(a)
        ok, _ = SH.applicable(cfg, SH.SHAPES["long_500k"])
        if ok:
            long_ok.add(a)
    assert long_ok == {"jamba-1.5-large-398b", "gemma3-27b", "mamba2-130m",
                       "h2o-danube-1.8b"}
    mixer = get_config("weathermixer-1b")
    for s in ("decode_32k", "long_500k"):
        ok, reason = SH.applicable(mixer, SH.SHAPES[s])
        assert not ok and "decode" in reason


def test_lm_training_loss_decreases():
    hist, _ = train("internlm2-1.8b", steps=40, batch=8, seq_len=64,
                    reduced=True, log_every=39, lr=2e-3)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.5, hist


def test_moe_training_stable():
    hist, _ = train("phi3.5-moe-42b-a6.6b", steps=20, batch=4, seq_len=32,
                    reduced=True, log_every=19, lr=1e-3)
    assert np.isfinite(hist[-1]["loss"])
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_rollout_finetune_runs():
    """The paper's randomized-rollout fine-tuning (§6) end to end."""
    hist, _ = train("weathermixer-1b", steps=10, batch=2, reduced=True,
                    rollout=3, log_every=9, lr=5e-4)
    assert np.isfinite(hist[-1]["loss"])


def test_checkpoint_train_resume(tmp_path):
    import os
    from repro.checkpoint import io as ckpt_io
    from repro.models import registry as M
    path = os.path.join(tmp_path, "ck")
    _, params = train("stablelm-3b", steps=5, batch=2, seq_len=32,
                      reduced=True, ckpt=path, log_every=100)
    cfg = get_config("stablelm-3b").reduced()
    like = M.init(jax.random.PRNGKey(0), cfg)
    p2, o2, step = ckpt_io.restore(path, like_params=like)
    assert step == 5
    got = jax.tree.leaves(p2)
    want = jax.tree.leaves(params)
    assert all(np.allclose(a, np.asarray(b)) for a, b in zip(got, want))
