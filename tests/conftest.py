"""Test-suite bootstrap.

* Ensures ``src/`` is importable (so ``PYTHONPATH=src`` is optional).
* If ``hypothesis`` is not installed (it is an optional dev dependency,
  see requirements-dev.txt), installs a minimal deterministic stand-in
  that supports the subset used here (``given``/``settings`` with
  ``st.integers``/``st.sampled_from``/``st.floats``/``st.booleans``) by
  running a fixed number of seeded pseudo-random examples.  Property
  tests then still execute -- with less adversarial search than real
  hypothesis, but the same invariants.
"""
import inspect
import os
import random
import sys
import types

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if _SRC not in sys.path:
    sys.path.insert(0, os.path.abspath(_SRC))


def _install_hypothesis_stub():
    mod = types.ModuleType("hypothesis")
    strategies = types.ModuleType("hypothesis.strategies")

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    strategies.integers = integers
    strategies.sampled_from = sampled_from
    strategies.floats = floats
    strategies.booleans = booleans

    def settings(max_examples=10, deadline=None, **_kw):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn
        return deco

    def given(**strats):
        def deco(fn):
            def wrapper():
                n = getattr(wrapper, "_stub_max_examples", 10)
                rng = random.Random(fn.__module__ + "." + fn.__name__)
                for _ in range(n):
                    fn(**{k: s.sample(rng) for k, s in strats.items()})
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            # zero-arg signature: pytest must not treat the strategy
            # parameters as fixtures
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco

    mod.given = given
    mod.settings = settings
    mod.strategies = strategies
    mod.HealthCheck = types.SimpleNamespace(all=lambda: [])
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_stub()
