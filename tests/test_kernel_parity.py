"""Interpret-mode parity suite for the fused-kernel hot path (ISSUE 2).

Single-process half: the Pallas compute engine (``kernel="pallas"``) must
match the XLA path within accumulation tolerance for forward AND
gradients (the custom VJP's backward GEMMs run the same Pallas kernel),
and ``ops.mixer_mlp`` must match the unfused two-matmul reference.

Distributed half (pseudo-mesh of 16 host-emulated devices, subprocess):
``ring_chunked`` == ``ring`` bit-for-bit and == ``rs`` within f32
reduction-order tolerance, with AD through the chunked ring -- see
tests/dist_scenarios.py::scenario_ring_chunked_parity.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import (JigsawConfig, linear_apply, linear_init,
                            mlp_apply, mlp_init)
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)
XLA = JigsawConfig(scheme="none", kernel="xla")
PALLAS = JigsawConfig(scheme="none", kernel="pallas")


def _tree_close(a, b, rtol, atol):
    flat_a, flat_b = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.allclose(np.asarray(x), np.asarray(y), rtol=rtol,
                           atol=atol) for x, y in zip(flat_a, flat_b))


# ---------------------------------------------------------------------------
# block shrink (satellite: the dead ``bm`` fix)
# ---------------------------------------------------------------------------

def test_block_dims_shrink_small_gemm():
    """A 16-row GEMM must run a 16-row block, not pad to block_m=256."""
    bm, bn, bk = ops.block_dims(16, 300, 40, block_m=256, block_n=256,
                                block_k=512)
    assert bm == 16          # sublane-aligned ceiling of m, not block_m
    assert bn == 256         # round_up(300, 128)=384 > block_n: keep 256
    assert bk == 128         # lane ceiling of k=40


def test_block_dims_alignment_floors():
    bm, bn, bk = ops.block_dims(3, 5, 7, block_m=256, block_n=256,
                                block_k=512)
    assert (bm, bn, bk) == (8, 128, 128)
    bm16, _, _ = ops.block_dims(3, 5, 7, block_m=256, block_n=256,
                                block_k=512, dtype=jnp.bfloat16)
    assert bm16 == 16        # bf16 sublane floor


def test_matmul_small_rows_correct():
    """Post-fix regression: tiny-m GEMMs still numerically correct."""
    k1, k2, k3 = jax.random.split(KEY, 3)
    x = jax.random.normal(k1, (16, 40))
    w = jax.random.normal(k2, (300, 40)) * 0.05
    b = jax.random.normal(k3, (300,)) * 0.1
    y = ops.matmul(x, w, b, epilogue="gelu")
    r = ref.block_matmul_ref(x, w, b, "gelu")
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), rtol=2e-5,
                               atol=2e-5)


# ---------------------------------------------------------------------------
# custom VJP: pallas grads == XLA grads
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("epilogue", ["none", "gelu", "silu"])
@pytest.mark.parametrize("bias", [True, False])
def test_matmul_grads_match_ref(epilogue, bias):
    k1, k2, k3 = jax.random.split(KEY, 3)
    x = jax.random.normal(k1, (24, 72))
    w = jax.random.normal(k2, (56, 72)) * 0.05
    b = jax.random.normal(k3, (56,)) * 0.1 if bias else None

    def f_pallas(*args):
        xx, ww, bb = (args if bias else (*args, None))
        return jnp.sum(ops.matmul(xx, ww, bb, epilogue=epilogue) ** 2)

    def f_ref(*args):
        xx, ww, bb = (args if bias else (*args, None))
        return jnp.sum(ref.block_matmul_ref(xx, ww, bb, epilogue) ** 2)

    args = (x, w, b) if bias else (x, w)
    nums = tuple(range(len(args)))
    gp = jax.grad(f_pallas, argnums=nums)(*args)
    gr = jax.grad(f_ref, argnums=nums)(*args)
    for a, c in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=2e-4, atol=2e-4)


def test_linear_apply_pallas_vs_xla_fwd_and_grad():
    params = linear_init(KEY, 72, 56)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 72))

    def loss(p, cfg):
        return jnp.sum(linear_apply(p, x, cfg) ** 2)

    vx, gx = jax.value_and_grad(loss)(params, XLA)
    vp, gp = jax.value_and_grad(loss)(params, PALLAS)
    np.testing.assert_allclose(float(vp), float(vx), rtol=1e-4)
    assert _tree_close(gp, gx, rtol=2e-3, atol=1e-3)


def test_linear_apply_pallas_fused_epilogue():
    """The epilogue knob fuses act(x@w.T+b) on the pallas path."""
    params = linear_init(KEY, 64, 48)
    x = jax.random.normal(jax.random.PRNGKey(2), (32, 64))
    y = linear_apply(params, x, PALLAS, epilogue="gelu")
    r = jax.nn.gelu(linear_apply(params, x, XLA))
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), rtol=2e-5,
                               atol=2e-5)


# ---------------------------------------------------------------------------
# fused mixer MLP vs the unfused two-matmul reference
# ---------------------------------------------------------------------------

def test_mixer_mlp_fwd_and_grad_vs_unfused():
    params = mlp_init(KEY, 64, 128, 64)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 24, 64))

    def loss(p, cfg):
        return jnp.sum(mlp_apply(p, x, cfg) ** 2)

    vx, gx = jax.value_and_grad(loss)(params, XLA)
    vp, gp = jax.value_and_grad(loss)(params, PALLAS)
    np.testing.assert_allclose(float(vp), float(vx), rtol=1e-4)
    assert _tree_close(gp, gx, rtol=2e-3, atol=1e-3)


def test_mixer_mlp_no_bias():
    params = mlp_init(KEY, 64, 96, 32, bias=False)
    x = jax.random.normal(jax.random.PRNGKey(4), (16, 64))
    y = mlp_apply(params, x, PALLAS)
    r = mlp_apply(params, x, XLA)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), rtol=1e-4,
                               atol=1e-4)


def test_weathermixer_pallas_forward_matches_xla():
    """Full reduced WeatherMixer forward: fused kernels == XLA engine."""
    from repro.configs.registry import get_config
    from repro.models import registry as M

    cfg = get_config("weathermixer-1b").reduced()
    params = M.init(KEY, cfg)
    batch = {"fields": jax.random.normal(
        KEY, (2, cfg.wm_lat, cfg.wm_lon, cfg.wm_channels))}
    yx, _ = M.apply(params, batch, cfg, XLA)
    yp, _ = M.apply(params, batch, cfg, PALLAS)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yx), rtol=2e-4,
                               atol=2e-4)


# ---------------------------------------------------------------------------
# bf16 precision policy (ISSUE 5): pallas vs xla parity + resume roundtrip
# ---------------------------------------------------------------------------

BF16_XLA = JigsawConfig(scheme="none", kernel="xla",
                        compute_dtype=jnp.bfloat16)
BF16_PALLAS = JigsawConfig(scheme="none", kernel="pallas",
                           compute_dtype=jnp.bfloat16)


def test_matmul_bf16_fwd_matches_ref():
    """bf16 pallas GEMM (fp32 MXU accumulation, 16-row sublane tiles)
    matches the xla bf16 path within one-rounding tolerance."""
    k1, k2, k3 = jax.random.split(KEY, 3)
    x = jax.random.normal(k1, (24, 72)).astype(jnp.bfloat16)
    w = (jax.random.normal(k2, (56, 72)) * 0.05).astype(jnp.bfloat16)
    b = (jax.random.normal(k3, (56,)) * 0.1).astype(jnp.bfloat16)
    y = ops.matmul(x, w, b, epilogue="gelu")
    assert y.dtype == jnp.bfloat16
    r = ref.block_matmul_ref(x.astype(jnp.float32), w.astype(jnp.float32),
                             b.astype(jnp.float32), "gelu")
    np.testing.assert_allclose(np.asarray(y, dtype=np.float32),
                               np.asarray(r), rtol=2e-2, atol=2e-2)


def test_block_dims_bf16_sublane_tiling():
    """bf16 GEMMs tile 16-row sublanes (f32: 8) -- the MXU constraint."""
    bm, _, _ = ops.block_dims(20, 128, 128, block_m=256, block_n=256,
                              block_k=512, dtype=jnp.bfloat16)
    assert bm == 32          # round_up(20, 16), not round_up(20, 8)=24
    bm8, _, _ = ops.block_dims(20, 128, 128, block_m=256, block_n=256,
                               block_k=512, dtype=jnp.float32)
    assert bm8 == 24


def test_linear_apply_bf16_pallas_vs_xla_fwd_and_grad():
    """bf16 policy through linear_apply: pallas == xla for forward AND
    grads (the custom VJP casts grads back to the param dtype)."""
    params = linear_init(KEY, 72, 56)
    params = jax.tree.map(lambda p: p.astype(jnp.bfloat16), params)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 72)
                          ).astype(jnp.bfloat16)

    def loss(p, cfg):
        return jnp.sum(linear_apply(p, x, cfg).astype(jnp.float32) ** 2)

    vx, gx = jax.value_and_grad(loss)(params, BF16_XLA)
    vp, gp = jax.value_and_grad(loss)(params, BF16_PALLAS)
    assert gp["w"].dtype == jnp.bfloat16     # grads back in param dtype
    np.testing.assert_allclose(float(vp), float(vx), rtol=2e-2)
    gx32 = jax.tree.map(lambda a: np.asarray(a, dtype=np.float32), gx)
    gp32 = jax.tree.map(lambda a: np.asarray(a, dtype=np.float32), gp)
    assert _tree_close(gp32, gx32, rtol=5e-2, atol=5e-1)


def test_mixer_mlp_bf16_fused_vs_unfused():
    params = mlp_init(KEY, 64, 128, 64)
    params = jax.tree.map(lambda p: p.astype(jnp.bfloat16), params)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 24, 64)
                          ).astype(jnp.bfloat16)
    yp = mlp_apply(params, x, BF16_PALLAS)
    yx = mlp_apply(params, x, BF16_XLA)
    assert yp.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(yp, dtype=np.float32),
                               np.asarray(yx, dtype=np.float32),
                               rtol=5e-2, atol=5e-2)


def test_bf16_policy_resume_roundtrip(tmp_path):
    """A bf16-policy run checkpointed through the sharded writer resumes
    exactly: params restored bf16, Adam master weights restored fp32,
    and the continued loss history matches the uninterrupted run."""
    from repro.launch.engine import EngineConfig, TrainEngine

    path = str(tmp_path / "ck")

    def engine(**kw):
        return TrainEngine("weathermixer-1b", config=EngineConfig(
            steps=4, batch=2, log_every=1, precision="bf16", **kw))

    full = engine()
    h_full = full.run()

    interrupted = engine(ckpt=path, ckpt_every=2)
    interrupted.run()
    resumed = engine(resume=path + "-2")
    assert resumed.step_idx == 3
    assert resumed.params["encoder"]["w"].dtype == jnp.bfloat16
    assert resumed.opt_state["master"]["encoder"]["w"].dtype == jnp.float32
    assert resumed.opt_state["mu"]["encoder"]["w"].dtype == jnp.float32
    # the bf16 params must equal the fp32 masters cast down (the masters
    # are the source of truth the update writes through)
    np.testing.assert_array_equal(
        np.asarray(resumed.params["encoder"]["w"], dtype=np.float32),
        np.asarray(resumed.opt_state["master"]["encoder"]["w"]
                   .astype(jnp.bfloat16), dtype=np.float32))
    h_res = resumed.run()
    tail = [h for h in h_full if h["step"] >= 3]
    assert len(h_res) == len(tail)
    for a, b in zip(tail, h_res):
        assert a["loss"] == b["loss"] and a["grad_norm"] == b["grad_norm"]


def test_bf16_policy_resume_rejects_precision_mismatch(tmp_path):
    from repro.launch.engine import EngineConfig, TrainEngine

    path = str(tmp_path / "ck")
    eng = TrainEngine("weathermixer-1b", config=EngineConfig(
        steps=2, batch=2, log_every=1, precision="bf16", ckpt=path))
    eng.run()
    with pytest.raises(ValueError, match="precision"):
        TrainEngine("weathermixer-1b", config=EngineConfig(
            steps=2, batch=2, log_every=1, resume=path))


# ---------------------------------------------------------------------------
# distributed half: chunked-ring parity on a 16-device pseudo-mesh
# ---------------------------------------------------------------------------

def test_ring_chunked_parity_pseudo_mesh():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    env.pop("JAX_PLATFORMS", None)
    script = os.path.join(os.path.dirname(__file__), "dist_scenarios.py")
    res = subprocess.run(
        [sys.executable, script, "ring_chunked_parity"], env=env,
        capture_output=True, text=True, timeout=600)
    assert res.returncode == 0 and "ALL-OK" in res.stdout, (
        f"\nstdout:\n{res.stdout[-3000:]}\nstderr:\n{res.stderr[-3000:]}")


# ---------------------------------------------------------------------------
# one-kernel ring (ISSUE 6): ring_fused == ring bit-identity + fused Cannon
# ---------------------------------------------------------------------------

def test_ring_fused_parity_pseudo_mesh():
    """The acceptance criterion: ring_fused == ring bit-for-bit (fwd +
    grads, fp32 and bf16, xla and pallas local GEMMs), the Pallas
    transposed-Cannon parity, the VMEM guard, and a 2-step engine A/B --
    see dist_scenarios.scenario_ring_fused_parity."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    env.pop("JAX_PLATFORMS", None)
    script = os.path.join(os.path.dirname(__file__), "dist_scenarios.py")
    res = subprocess.run(
        [sys.executable, script, "ring_fused_parity"], env=env,
        capture_output=True, text=True, timeout=600)
    assert res.returncode == 0 and "ALL-OK" in res.stdout, (
        f"\nstdout:\n{res.stdout[-3000:]}\nstderr:\n{res.stderr[-3000:]}")


def test_jigsaw_config_validation():
    """Unknown knobs raise; silently-ignored combinations warn."""
    import warnings

    with pytest.raises(ValueError, match="scheme"):
        JigsawConfig(scheme="3d")
    with pytest.raises(ValueError, match="impl"):
        JigsawConfig(impl="ring_fuzed")
    with pytest.raises(ValueError, match="kernel"):
        JigsawConfig(kernel="triton")
    with pytest.warns(UserWarning, match="ignores"):
        JigsawConfig(scheme="2d", impl="ring_fused")
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # no spurious warnings
        JigsawConfig(scheme="1d", impl="ring_fused", kernel="pallas")
        JigsawConfig(scheme="2d")               # default impl: fine


def test_fused_ring_p1_smoke():
    """p=1 runs the fused op without any ring (no RDMA primitives are
    even traced); forward and grads equal the dense GEMM on both local
    engines."""
    from repro.kernels import fused_ring

    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (8, 24, 64))
    w = jax.random.normal(k2, (48, 64)) * 0.05

    def dense(xx, ww):
        return jnp.sum(jnp.einsum("btd,md->btm", xx, ww) ** 2)

    for kern in ("xla", "pallas"):
        def fused(xx, ww):
            y = fused_ring.fused_ring_matmul(
                xx, ww, axis_name="model", axis_size=1, kernel=kern)
            return jnp.sum(y ** 2)

        v, g = jax.value_and_grad(fused, argnums=(0, 1))(x, w)
        vr, gr = jax.value_and_grad(dense, argnums=(0, 1))(x, w)
        np.testing.assert_allclose(float(v), float(vr), rtol=1e-4)
        for a, b in zip(g, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-3, atol=1e-4)


def test_cannon_t_step_parity():
    """The fused multiply-accumulate step kernel (acc + w @ x, f32 VMEM
    accumulation) matches the reference einsum for forward AND grads
    (custom VJP: dw/dx ride the same blocked machinery)."""
    from repro.kernels import fused_ring

    k1, k2, k3 = jax.random.split(KEY, 3)
    w = jax.random.normal(k1, (20, 24)) * 0.1
    x = jax.random.normal(k2, (3, 24, 40))
    acc = jax.random.normal(k3, (3, 20, 40))

    def f_pallas(ww, xx, aa):
        return jnp.sum(fused_ring.cannon_t_step(ww, xx, aa) ** 2)

    def f_ref(ww, xx, aa):
        return jnp.sum((aa + jnp.einsum("mt,btc->bmc", ww, xx)) ** 2)

    y = fused_ring.cannon_t_step(w, x, acc)
    r = acc + jnp.einsum("mt,btc->bmc", w, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), rtol=2e-5,
                               atol=2e-5)
    gp = jax.grad(f_pallas, argnums=(0, 1, 2))(w, x, acc)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(w, x, acc)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)
    # None starts a fresh accumulator
    y0 = fused_ring.cannon_t_step(w, x, None)
    np.testing.assert_allclose(np.asarray(y0),
                               np.asarray(jnp.einsum("mt,btc->bmc", w, x)),
                               rtol=2e-5, atol=2e-5)


def test_fused_ring_vmem_guard_units():
    """The budget guard's arithmetic: footprint scales with the chunk
    tiles, and the backend parameterization keeps CPU on the fallback."""
    from repro.kernels import fused_ring

    small = fused_ring.ring_footprint_bytes(64, 64, 512, 8, jnp.float32,
                                            jnp.float32)
    big = fused_ring.ring_footprint_bytes(4096, 4096, 65536, 8,
                                          jnp.float32, jnp.float32)
    assert small < big
    assert fused_ring.fits_vmem(64, 64, 512, 8, jnp.float32, jnp.float32)
    assert not fused_ring.fits_vmem(4096, 4096, 65536, 8, jnp.float32,
                                    jnp.float32)
    # bf16 wire halves the ring-buffer bytes
    bf = fused_ring.ring_footprint_bytes(64, 64, 512, 8, jnp.bfloat16,
                                         jnp.float32)
    assert bf < small


def test_comm_schedule_fused_rows():
    """ring_fused hides the hop add in-kernel: strictly more overlappable
    flops per hop than ring_chunked at identical wire bytes."""
    from repro.core import jigsaw

    ring = jigsaw.comm_schedule_jigsaw_1d(4096, 4096, 512, 8, impl="ring")
    chunked = jigsaw.comm_schedule_jigsaw_1d(4096, 4096, 512, 8,
                                             impl="ring_chunked")
    fused = jigsaw.comm_schedule_jigsaw_1d(4096, 4096, 512, 8,
                                           impl="ring_fused")
    assert ring.flops_per_hop == 0.0
    assert fused.flops_per_hop > chunked.flops_per_hop > 0
    assert fused.bytes_per_hop == chunked.bytes_per_hop == ring.bytes_per_hop
    assert fused.bytes_per_device == chunked.bytes_per_device
    assert fused.scheme == "jigsaw-1d-ring_fused"
    r = fused.overlap_ratio(50e9, 197e12)
    assert r >= chunked.overlap_ratio(50e9, 197e12)
    # legacy bool still works
    legacy = jigsaw.comm_schedule_jigsaw_1d(4096, 4096, 512, 8,
                                            chunked=True)
    assert legacy.scheme == "jigsaw-1d-ring_chunked"
    with pytest.raises(ValueError, match="impl"):
        jigsaw.comm_schedule_jigsaw_1d(4096, 4096, 512, 8, impl="rs")
