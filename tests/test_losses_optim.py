"""Losses, optimizer, schedule, checkpoint unit tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.checkpoint import io as ckpt_io
from repro.optim import adam, schedule as sched
from repro.train import loss as losses


# ---------------- losses ----------------

def test_latitude_weights_mean_one():
    w = losses.latitude_weights(33)
    assert np.isclose(float(jnp.mean(w)), 1.0, atol=1e-6)
    assert float(w[16]) > float(w[0])  # equator > pole


def test_pressure_level_weights():
    w = losses.pressure_level_weights(69)
    assert w.shape == (69,)
    assert np.isclose(float(w[4]), 1.0)        # top level of var 0
    assert np.isclose(float(w[4 + 12]), 0.3)   # lowest level of var 0


def test_lm_cross_entropy_matches_manual():
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 32))
    labels = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 30)
    got = losses.lm_cross_entropy(logits, labels, vocab_size=30)
    lm = jax.nn.log_softmax(
        jnp.where(jnp.arange(32) >= 30, -1e30, logits.astype(jnp.float32)))
    want = -jnp.mean(jnp.take_along_axis(lm, labels[..., None], -1))
    assert np.isclose(float(got), float(want), rtol=1e-5)


def test_lm_cross_entropy_ignores_padded_vocab():
    """Huge logits on padded ids must not affect the loss."""
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 32))
    labels = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 30)
    a = losses.lm_cross_entropy(logits, labels, vocab_size=30)
    poisoned = logits.at[..., 30:].set(1e4)
    b = losses.lm_cross_entropy(poisoned, labels, vocab_size=30)
    assert np.isclose(float(a), float(b), rtol=1e-5)


def test_weighted_mse_masks():
    pred = jnp.ones((1, 4, 4, 2))
    tgt = jnp.zeros((1, 4, 4, 2))
    lat_w = jnp.array([0.0, 2.0, 2.0, 0.0])
    assert np.isclose(float(losses.weighted_mse(pred, tgt, lat_w)), 1.0)


# ---------------- optimizer ----------------

def test_adam_matches_reference_step():
    params = {"w": jnp.array([1.0, -2.0])}
    grads = {"w": jnp.array([0.5, 0.5])}
    cfg = adam.AdamConfig(b1=0.9, b2=0.999, eps=1e-8, grad_clip=None)
    state = adam.init(params, cfg)
    new, st2 = adam.update(params, grads, state, jnp.float32(0.1), cfg)
    # bias-corrected first step: delta = lr * g/|g| = lr (sign)
    np.testing.assert_allclose(np.asarray(new["w"]),
                               np.asarray(params["w"]) - 0.1, rtol=1e-4)
    assert int(st2["step"]) == 1


def test_grad_clip():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = adam.clip_by_global_norm(g, 1.0)
    assert np.isclose(float(norm), 20.0)
    assert np.isclose(float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(step=st.integers(0, 200_000))
def test_schedule_bounds(step):
    lr = float(sched.warmup_cosine(step, base_lr=1e-4, warmup_steps=1000,
                                   total_steps=100_000, min_lr=1e-5))
    assert 1e-6 - 1e-9 <= lr <= 1e-4 + 1e-9


def test_schedule_shape():
    assert np.isclose(float(sched.warmup_cosine(0, init_lr=1e-6)), 1e-6,
                      rtol=1e-5)
    assert np.isclose(float(sched.warmup_cosine(1000, base_lr=1e-4,
                                                warmup_steps=1000)), 1e-4)
    end = float(sched.warmup_cosine(100_000, base_lr=1e-4,
                                    total_steps=100_000, min_lr=1e-5))
    assert np.isclose(end, 1e-5, rtol=1e-3)


# ---------------- checkpoint ----------------

def test_checkpoint_roundtrip(tmp_path):
    params = {"layer": {"w": jnp.arange(6.0).reshape(2, 3),
                        "b": jnp.zeros((3,))},
              "embed": {"table": jnp.ones((4, 2))}}
    opt = adam.init(params, adam.AdamConfig())
    path = os.path.join(tmp_path, "ck")
    ckpt_io.save(path, params, opt, step=42, extra={"arch": "t"})
    p2, o2, step = ckpt_io.restore(path, like_params=params, like_opt=opt)
    assert step == 42
    np.testing.assert_array_equal(p2["layer"]["w"],
                                  np.asarray(params["layer"]["w"]))
    assert int(o2["step"]) == 0


def test_checkpoint_shape_validation(tmp_path):
    params = {"w": jnp.zeros((2, 2))}
    path = os.path.join(tmp_path, "ck")
    ckpt_io.save(path, params, step=1)
    import pytest
    with pytest.raises(ValueError):
        ckpt_io.restore(path, like_params={"w": jnp.zeros((3, 3))})
