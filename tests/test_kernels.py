"""Pallas kernel correctness: shape/dtype sweeps (hypothesis) against the
pure-jnp oracles in kernels/ref.py, executed in interpret mode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _mk(m, k, n, dtype, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k1, (m, k), dtype)
    w = (jax.random.normal(k2, (n, k), jnp.float32) * 0.05).astype(dtype)
    b = (jax.random.normal(k3, (n,), jnp.float32) * 0.1).astype(dtype)
    return x, w, b


@settings(max_examples=12, deadline=None)
@given(
    m=st.integers(1, 5), k=st.integers(1, 5), n=st.integers(1, 5),
    mul=st.sampled_from([64, 96, 128]),
    epilogue=st.sampled_from(["none", "gelu", "silu"]),
)
def test_matmul_shape_sweep(m, k, n, mul, epilogue):
    x, w, b = _mk(m * mul, k * mul, n * mul, jnp.float32)
    y = ops.matmul(x, w, b, epilogue=epilogue, block_m=128, block_n=128,
                   block_k=128)
    r = ref.block_matmul_ref(x, w, b, epilogue)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 3e-2)])
def test_matmul_dtypes(dtype, tol):
    x, w, b = _mk(256, 384, 192, dtype)
    y = ops.matmul(x, w, b, epilogue="gelu")
    r = ref.block_matmul_ref(x, w, b, "gelu")
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(r, np.float32), rtol=tol, atol=tol)


def test_matmul_no_bias():
    x, w, _ = _mk(128, 128, 128, jnp.float32)
    y = ops.matmul(x, w, None)
    r = ref.block_matmul_ref(x, w, None)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), rtol=2e-5)


def test_matmul_unaligned_padding():
    """Wrapper pads ragged dims and slices back."""
    x, w, b = _mk(300, 700, 130, jnp.float32, seed=3)
    y = ops.matmul(x, w, b)
    r = ref.block_matmul_ref(x, w, b)
    assert y.shape == (300, 130)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), rtol=2e-5,
                               atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(rows=st.sampled_from([64, 128, 200]),
       d_in=st.sampled_from([128, 256]),
       d_h=st.sampled_from([128, 384]),
       d_out=st.sampled_from([64, 256]),
       lead=st.integers(1, 3))
def test_mixer_mlp_sweep(rows, d_in, d_h, d_out, lead):
    k1, k2, k3 = jax.random.split(KEY, 3)
    x = jax.random.normal(k1, (lead, rows, d_in))
    w1 = jax.random.normal(k2, (d_h, d_in)) * 0.05
    b1 = jnp.zeros((d_h,))
    w2 = jax.random.normal(k3, (d_out, d_h)) * 0.05
    b2 = jnp.ones((d_out,)) * 0.1
    y = ops.mixer_mlp(x, w1, b1, w2, b2)
    r = ref.mixer_mlp_ref(x, w1, b1, w2, b2)
    assert y.shape == (lead, rows, d_out)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), rtol=1e-4,
                               atol=1e-4)


def test_mixer_mlp_equals_model_mlp():
    """The fused kernel matches the model's (unfused) mixer MLP."""
    from repro.core.api import JigsawConfig, mlp_apply, mlp_init
    params = mlp_init(KEY, 128, 256, 128)
    x = jax.random.normal(KEY, (2, 64, 128))
    r = mlp_apply(params, x, JigsawConfig(scheme="none"))
    y = ops.mixer_mlp(x, params["fc1"]["w"], params["fc1"]["b"],
                      params["fc2"]["w"], params["fc2"]["b"])
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), rtol=2e-4,
                               atol=2e-4)



def test_ssd_intra_kernel_matches_ref():
    from hypothesis import given, settings, strategies as st
    k = jax.random.split(KEY, 5)
    g, q, n, p = 6, 64, 32, 16
    c = jax.random.normal(k[0], (g, q, n)) * 0.3
    b = jax.random.normal(k[1], (g, q, n)) * 0.3
    x = jax.random.normal(k[2], (g, q, p))
    dt = jax.nn.softplus(jax.random.normal(k[3], (g, q)))
    da = -jnp.cumsum(dt * 0.1, axis=1)
    y = ops.ssd_intra(c, b, x, dt, da)
    r = ref.ssd_intra_ref(c, b, x, dt, da)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), rtol=2e-4,
                               atol=2e-4)


def test_ssd_intra_kernel_matches_model_scan():
    """Kernel == the intra-chunk part of the model's _ssd_chunked."""
    from repro.models.layers import _ssd_chunked
    bsz, s, h, p, n, chunk = 1, 64, 2, 8, 16, 64   # single chunk
    k = jax.random.split(KEY, 5)
    x = jax.random.normal(k[0], (bsz, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(k[1], (bsz, s, h)))
    A = -jnp.exp(jax.random.normal(k[2], (h,)) * 0.3)
    B = jax.random.normal(k[3], (bsz, s, 1, n)) * 0.3
    C = jax.random.normal(k[4], (bsz, s, 1, n)) * 0.3
    y_full, _ = _ssd_chunked(x, dt, A, B, C, chunk)
    # kernel arrangement: G = bsz*h blocks of one chunk each
    Bh = jnp.repeat(B, h, axis=2)
    Ch = jnp.repeat(C, h, axis=2)
    dac = jnp.cumsum(dt * A[None, None, :], axis=1)
    tog = lambda t: jnp.moveaxis(t, 2, 1).reshape((bsz * h, s) + t.shape[3:])
    y_k = ops.ssd_intra(tog(Ch), tog(Bh), tog(x),
                        jnp.moveaxis(dt, 2, 1).reshape(bsz * h, s),
                        jnp.moveaxis(dac, 2, 1).reshape(bsz * h, s))
    y_k = jnp.moveaxis(y_k.reshape(bsz, h, s, p), 1, 2)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_full),
                               rtol=2e-3, atol=2e-3)
