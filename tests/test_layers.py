"""Layer-level unit + property tests: SSD scan vs naive recurrence, MoE
dispatch invariants, attention masking, RoPE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.api import JigsawConfig
from repro.models import layers as L

KEY = jax.random.PRNGKey(0)
NONE = JigsawConfig(scheme="none")


# ---------------- SSD (mamba2) ----------------

def naive_ssm(x, dt, A, B, C):
    """Reference O(S*N) sequential recurrence:
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ; y_t = C_t . h_t."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    rep = h // B.shape[2]
    Bh = np.repeat(np.asarray(B), rep, axis=2)
    Ch = np.repeat(np.asarray(C), rep, axis=2)
    x, dt, A = map(np.asarray, (x, dt, A))
    ht = np.zeros((b, h, p, n))
    ys = np.zeros((b, s, h, p))
    for t in range(s):
        dA = np.exp(dt[:, t] * A[None, :])                  # [b, h]
        upd = np.einsum("bh,bhn,bhp->bhpn", dt[:, t], Bh[:, t], x[:, t])
        ht = ht * dA[:, :, None, None] + upd
        ys[:, t] = np.einsum("bhn,bhpn->bhp", Ch[:, t], ht)
    return ys, ht


@pytest.mark.parametrize("s,chunk", [(16, 4), (24, 8), (7, 4)])
def test_ssd_chunked_equals_naive(s, chunk):
    b, h, p, n, g = 2, 4, 8, 16, 2
    k = jax.random.split(KEY, 5)
    x = jax.random.normal(k[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(k[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(k[2], (h,)) * 0.5)
    B = jax.random.normal(k[3], (b, s, g, n)) * 0.3
    C = jax.random.normal(k[4], (b, s, g, n)) * 0.3
    y, hT = L._ssd_chunked(x, dt, A, B, C, chunk)
    y_ref, h_ref = naive_ssm(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)


def test_mamba2_decode_matches_train():
    """Token-by-token decode == full-sequence (chunked) forward."""
    d_model, heads, hd, state, g = 32, 4, 16, 8, 2
    params = L.mamba2_init(KEY, d_model, d_state=state, n_heads=heads,
                           head_dim=hd, n_groups=g, expand=2)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, d_model)) * 0.5
    full, _ = L.mamba2_apply(params, x, d_state=state, n_heads=heads,
                             head_dim=hd, n_groups=g, chunk=4, cfg=NONE)
    conv_dim = heads * hd + 2 * g * state
    st_ = {"conv": jnp.zeros((2, 3, conv_dim)),
           "ssm": jnp.zeros((2, heads, hd, state))}
    outs = []
    for t in range(10):
        o, st_ = L.mamba2_apply(params, x[:, t:t + 1], d_state=state,
                                n_heads=heads, head_dim=hd, n_groups=g,
                                cfg=NONE, state=st_)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


# ---------------- MoE ----------------

def test_moe_output_shape_and_aux():
    p = L.moe_init(KEY, 32, 64, 4)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    y, aux = L.moe_apply(p, x, top_k=2, cfg=NONE)
    assert y.shape == x.shape
    assert float(aux) >= 1.0 - 1e-3  # load-balance loss lower bound is 1


def test_moe_capacity_drops_tokens():
    """With tiny capacity some tokens get zero output (dropped)."""
    p = L.moe_init(KEY, 16, 32, 4)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 16))
    y_full, _ = L.moe_apply(p, x, top_k=1, capacity_factor=8.0, cfg=NONE)
    y_tiny, _ = L.moe_apply(p, x, top_k=1, capacity_factor=0.1, cfg=NONE)
    zero_rows = np.asarray(jnp.all(y_tiny == 0, axis=-1)).mean()
    assert zero_rows > 0.3
    assert not np.allclose(np.asarray(y_full), np.asarray(y_tiny))


def test_moe_single_expert_equals_dense():
    """1 expert, top-1, ample capacity == plain FFN with that expert."""
    p = L.moe_init(KEY, 16, 32, 1)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y, _ = L.moe_apply(p, x, top_k=1, capacity_factor=4.0, cfg=NONE)
    w = p["experts"]
    h = jax.nn.silu(jnp.einsum("bsd,fd->bsf", x, w["gate"][0])) * \
        jnp.einsum("bsd,fd->bsf", x, w["up"][0])
    want = jnp.einsum("bsf,df->bsd", h, w["down"][0])
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-4,
                               atol=1e-5)


# ---------------- attention ----------------

def test_causal_mask():
    """Future tokens must not influence logits."""
    params = L.attention_init(KEY, 32, 4, 2, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32))
    pos = jnp.arange(8)
    out1, _ = L.attention_apply(params, x, n_heads=4, n_kv_heads=2,
                                d_head=8, positions=pos, cfg=NONE)
    x2 = x.at[:, -1].set(99.0)  # perturb the last token
    out2, _ = L.attention_apply(params, x2, n_heads=4, n_kv_heads=2,
                                d_head=8, positions=pos, cfg=NONE)
    np.testing.assert_allclose(np.asarray(out1[:, :-1]),
                               np.asarray(out2[:, :-1]), rtol=1e-5,
                               atol=1e-5)


def test_sliding_window_equals_full_for_large_window():
    params = L.attention_init(KEY, 32, 4, 4, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 32))
    pos = jnp.arange(12)
    a, _ = L.attention_apply(params, x, n_heads=4, n_kv_heads=4, d_head=8,
                             positions=pos, cfg=NONE, window=None)
    b, _ = L.attention_apply(params, x, n_heads=4, n_kv_heads=4, d_head=8,
                             positions=pos, cfg=NONE, window=100)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-6)
    c, _ = L.attention_apply(params, x, n_heads=4, n_kv_heads=4, d_head=8,
                             positions=pos, cfg=NONE, window=2)
    assert not np.allclose(np.asarray(a), np.asarray(c), atol=1e-4)


def test_rolling_window_cache_decode():
    """Rolling cache (size w) decode == full-cache decode with window w,
    once more than w tokens have been written."""
    heads, hd, d = 2, 8, 16
    params = L.attention_init(KEY, d, heads, heads, hd)
    w = 4
    toks = jax.random.normal(jax.random.PRNGKey(1), (1, 10, d))
    full = {"k": jnp.zeros((1, 16, heads, hd)),
            "v": jnp.zeros((1, 16, heads, hd)), "pos": jnp.zeros(1, jnp.int32)}
    roll = {"k": jnp.zeros((1, w, heads, hd)),
            "v": jnp.zeros((1, w, heads, hd)), "pos": jnp.zeros(1, jnp.int32)}
    for t in range(10):
        xt = toks[:, t:t + 1]
        pos = jnp.full((1,), t, jnp.int32)
        of, nf = L.attention_apply(params, xt, n_heads=heads,
                                   n_kv_heads=heads, d_head=hd,
                                   positions=pos[:, None], cfg=NONE,
                                   window=w,
                                   kv_cache={**full, "pos": pos},
                                   rolling=False)
        full = {"k": nf["k"], "v": nf["v"], "pos": pos}
        orr, nr = L.attention_apply(params, xt, n_heads=heads,
                                    n_kv_heads=heads, d_head=hd,
                                    positions=pos[:, None], cfg=NONE,
                                    window=w,
                                    kv_cache={**roll, "pos": pos},
                                    rolling=True)
        roll = {"k": nr["k"], "v": nr["v"], "pos": pos}
        np.testing.assert_allclose(np.asarray(of), np.asarray(orr),
                                   rtol=1e-4, atol=1e-5,
                                   err_msg=f"step {t}")


def test_rope_relative():
    """RoPE scores depend only on relative distance."""
    x = jax.random.normal(KEY, (1, 2, 1, 16))
    q1 = L.rope(x, jnp.array([0, 3]))
    q2 = L.rope(x, jnp.array([5, 8]))
    s1 = float(jnp.sum(q1[0, 0, 0] * q1[0, 1, 0]))
    s2 = float(jnp.sum(q2[0, 0, 0] * q2[0, 1, 0]))
    assert np.isclose(s1, s2, rtol=1e-4)


def test_gqa_repeat():
    k = jnp.arange(12.0).reshape(1, 1, 3, 4)
    r = L._repeat_kv(k, 2)
    assert r.shape == (1, 1, 6, 4)
    np.testing.assert_array_equal(np.asarray(r[0, 0, 0]),
                                  np.asarray(r[0, 0, 1]))


# ---------------- chunked attention ----------------

def test_sdpa_chunked_matches_reference():
    """Online-softmax chunked attention == exact sdpa (fwd + grad),
    causal / windowed / ragged shapes."""
    for (sq, w) in [(64, None), (100, None), (64, 16), (37, 8)]:
        q = jax.random.normal(KEY, (2, sq, 4, 16))
        k = jax.random.normal(jax.random.PRNGKey(1), (2, sq, 4, 16))
        v = jax.random.normal(jax.random.PRNGKey(2), (2, sq, 4, 16))
        pos = jnp.arange(sq)
        ref = L.sdpa(q, k, v, q_pos=pos, kv_pos=pos, causal=True, window=w)
        got = L.sdpa_chunked(q, k, v, q_pos=pos, kv_pos=pos, causal=True,
                             window=w, q_chunk=16, kv_chunk=32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=3e-5, rtol=3e-5,
                                   err_msg=f"sq={sq} w={w}")

    def loss(fn, qq, **kw):
        return jnp.sum(fn(qq, k, v, **kw) ** 2)

    sq = 32
    q = jax.random.normal(KEY, (1, sq, 2, 8))
    k = jax.random.normal(jax.random.PRNGKey(3), (1, sq, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(4), (1, sq, 2, 8))
    pos = jnp.arange(sq)
    g1 = jax.grad(lambda qq: loss(L.sdpa, qq, q_pos=pos, kv_pos=pos))(q)
    g2 = jax.grad(lambda qq: loss(L.sdpa_chunked, qq, q_pos=pos,
                                  kv_pos=pos, q_chunk=8, kv_chunk=8))(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4,
                               rtol=1e-4)


def test_model_with_q_chunk_matches_reference():
    """Whole-model forward with attn_q_chunk == reference attention."""
    from repro.configs.registry import get_config
    from repro.launch import shapes as SHP
    from repro.models import registry as MR
    cfg = get_config("internlm2-1.8b").reduced()
    params = MR.init(KEY, cfg)
    batch = {"tokens": jax.random.randint(KEY, (2, 64), 0, cfg.vocab_size)}
    ref, _ = MR.apply(params, batch, cfg, SHP.jigsaw_for(cfg))
    cfg2 = cfg.replace(attn_q_chunk=16)
    got, _ = MR.apply(params, batch, cfg2, SHP.jigsaw_for(cfg2))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
