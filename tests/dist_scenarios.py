"""Distributed-correctness scenarios (run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=16 by test_distributed.py;
NOT collected by pytest directly).

Each scenario asserts numerical equivalence between a Jigsaw-distributed
computation and its dense single-device reference -- the paper's own
correctness invariant (Fig. 4: "equivalent architectures across 1-, 2-,
4-way parallel models").
"""
import os
import sys

if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=16")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import jigsaw  # noqa: E402
from repro.core.api import JigsawConfig, linear_apply, linear_init  # noqa: E402
from repro.core.sharding import RULES_1D, RULES_2D  # noqa: E402
from repro.launch.mesh import make_host_mesh  # noqa: E402

AUTO = (jax.sharding.AxisType.Auto,)


def _loss(p, x, cfg):
    return jnp.sum(linear_apply(p, x, cfg) ** 2)


def check(name, ok):
    print(f"  [{'ok' if ok else 'FAIL'}] {name}")
    if not ok:
        raise AssertionError(name)


def scenario_jigsaw_1d():
    """1-D Jigsaw (2-way paper scheme generalized to 8-way): fwd + grads
    equal dense for every impl."""
    mesh = make_host_mesh(model=8, data=2)
    params = linear_init(jax.random.PRNGKey(0), 64, 128)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64))
    ref_v, ref_g = jax.value_and_grad(_loss)(params, x,
                                             JigsawConfig(scheme="none"))
    with jax.set_mesh(mesh):
        for impl in ["ring", "ring_chunked", "rs", "allreduce", "gspmd"]:
            v, g = jax.jit(jax.value_and_grad(_loss), static_argnums=2)(
                params, x, JigsawConfig(impl=impl))
            ok = np.allclose(v, ref_v, rtol=1e-4) and all(
                np.allclose(g[k], ref_g[k], rtol=1e-3, atol=1e-4)
                for k in ("w", "b"))
            check(f"1d impl={impl} fwd+grad == dense", ok)


def scenario_jigsaw_1d_fsdp():
    """FSDP-hybrid (w also sharded over data) matches dense."""
    mesh = make_host_mesh(model=4, data=4)
    params = linear_init(jax.random.PRNGKey(0), 64, 128)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 64))
    ref_v, ref_g = jax.value_and_grad(_loss)(params, x,
                                             JigsawConfig(scheme="none"))
    with jax.set_mesh(mesh):
        cfg = JigsawConfig(impl="rs", fsdp=True)
        pp = {"w": jax.device_put(params["w"],
                                  NamedSharding(mesh, P("data", "model"))),
              "b": jax.device_put(params["b"],
                                  NamedSharding(mesh, P("model")))}
        v, g = jax.jit(jax.value_and_grad(_loss), static_argnums=2)(
            pp, x, cfg)
        ok = np.allclose(v, ref_v, rtol=1e-4) and all(
            np.allclose(g[k], ref_g[k], rtol=1e-3, atol=1e-4)
            for k in ("w", "b"))
        check("1d fsdp fwd+grad == dense", ok)


def scenario_jigsaw_2d():
    """2-D Jigsaw (4-way paper scheme at 2x2, generalized at 4x4):
    Cannon fwd + grads equal dense; transposed variant too."""
    params = linear_init(jax.random.PRNGKey(0), 64, 128)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64))
    ref_v, ref_g = jax.value_and_grad(_loss)(params, x,
                                             JigsawConfig(scheme="none"))
    for q, model in [(2, 4), (4, 16)]:
        data = 16 // model if model < 16 else 1
        mesh = jax.make_mesh((data, q, q), ("data", "mdom", "mtp"),
                             axis_types=AUTO * 3)
        with jax.set_mesh(mesh):
            cfg = JigsawConfig(rules=RULES_2D, scheme="2d")
            v, g = jax.jit(jax.value_and_grad(_loss), static_argnums=2)(
                params, x, cfg)
            ok = np.allclose(v, ref_v, rtol=1e-4) and all(
                np.allclose(g[k], ref_g[k], rtol=1e-3, atol=1e-4)
                for k in ("w", "b"))
            check(f"2d cannon {q}x{q} fwd+grad == dense", ok)

    # transposed Cannon (token-mixing): y = w @ x over dim -2
    w = jax.random.normal(jax.random.PRNGKey(2), (32, 16)) * 0.1
    bias = jax.random.normal(jax.random.PRNGKey(3), (32,)) * 0.1
    ref = jnp.einsum("mt,btc->bmc", w, x) + bias[None, :, None]
    mesh = jax.make_mesh((1, 4, 4), ("data", "mdom", "mtp"),
                         axis_types=AUTO * 3)
    with jax.set_mesh(mesh):
        y = jax.jit(lambda xx, ww, bb: jigsaw.jigsaw_linear_2d_t(
            xx, ww, bb, rules=RULES_2D))(x, w, bias)
    check("2d_t cannon 4x4 (transposed MLP) == dense",
          np.allclose(y, ref, rtol=1e-4, atol=1e-5))


def scenario_ring_chunked_parity():
    """Interpret-mode parity of the chunked ring and the Pallas kernel
    path (ISSUE 2): ring_chunked == ring bit-for-bit (identical chunk
    walk), == rs within f32 reduction-order tolerance; kernel="pallas"
    matches kernel="xla" for fwd AND grads (AD through the chunked ring
    runs the custom-VJP backward GEMMs)."""
    mesh = make_host_mesh(model=8, data=2)
    params = linear_init(jax.random.PRNGKey(0), 64, 128)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64))
    ref_v, ref_g = jax.value_and_grad(_loss)(params, x,
                                             JigsawConfig(scheme="none"))
    with jax.set_mesh(mesh):
        outs = {}
        for impl in ("ring", "ring_chunked", "rs"):
            outs[impl] = np.asarray(jax.jit(linear_apply, static_argnums=2)(
                params, x, JigsawConfig(impl=impl)))
        check("ring_chunked == ring bit-for-bit",
              np.array_equal(outs["ring_chunked"], outs["ring"]))
        check("ring_chunked == rs (f32 reduction tolerance)",
              np.allclose(outs["ring_chunked"], outs["rs"],
                          rtol=1e-6, atol=1e-6))
        # AD through the chunked ring
        v, g = jax.jit(jax.value_and_grad(_loss), static_argnums=2)(
            params, x, JigsawConfig(impl="ring_chunked"))
        ok = np.allclose(v, ref_v, rtol=1e-4) and all(
            np.allclose(g[k], ref_g[k], rtol=1e-3, atol=1e-4)
            for k in ("w", "b"))
        check("ring_chunked kernel=xla fwd+grad == dense", ok)

    # pallas local GEMMs: interpret mode is slow, so a 4-way mesh
    mesh4 = make_host_mesh(model=4, data=1)
    with jax.set_mesh(mesh4):
        cfg = JigsawConfig(impl="ring_chunked", kernel="pallas")
        v, g = jax.jit(jax.value_and_grad(_loss), static_argnums=2)(
            params, x, cfg)
        ok = np.allclose(v, ref_v, rtol=1e-4) and all(
            np.allclose(g[k], ref_g[k], rtol=1e-3, atol=1e-4)
            for k in ("w", "b"))
        check("ring_chunked kernel=pallas fwd+grad == dense", ok)
        y = jax.jit(linear_apply, static_argnums=2)(
            params, x, JigsawConfig(impl="rs", kernel="pallas"))
        yx = jax.jit(linear_apply, static_argnums=2)(
            params, x, JigsawConfig(impl="rs"))
        check("rs kernel=pallas == xla",
              np.allclose(np.asarray(y), np.asarray(yx),
                          rtol=1e-5, atol=1e-5))

    # 2-D Cannon with pallas local blocks (paper's 4-way at 2x2)
    mesh2 = jax.make_mesh((1, 2, 2), ("data", "mdom", "mtp"),
                          axis_types=AUTO * 3)
    with jax.set_mesh(mesh2):
        cfg2 = JigsawConfig(rules=RULES_2D, scheme="2d", kernel="pallas")
        v, g = jax.jit(jax.value_and_grad(_loss), static_argnums=2)(
            params, x, cfg2)
        ok = np.allclose(v, ref_v, rtol=1e-4) and all(
            np.allclose(g[k], ref_g[k], rtol=1e-3, atol=1e-4)
            for k in ("w", "b"))
        check("2d cannon kernel=pallas fwd+grad == dense", ok)


def scenario_ring_fused_parity():
    """The one-kernel ring (ISSUE 6): impl="ring_fused" must be
    BIT-identical to impl="ring" -- forward and grads -- under fp32 and
    bf16 policies and both local-GEMM engines (the acceptance criterion;
    on CPU this exercises the deterministic chunk-granular fallback whose
    cast points mirror the TPU kernel's).  Also: the Pallas transposed
    Cannon (jigsaw_linear_2d_t kernel="pallas") vs the dot_general
    lowering, the VMEM-budget guard, and a 2-step TrainEngine A/B."""
    from repro.kernels import fused_ring

    params = linear_init(jax.random.PRNGKey(0), 64, 128)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64))

    def run(impl, kern, cd):
        cfg = JigsawConfig(impl=impl, kernel=kern, compute_dtype=cd)
        v, g = jax.jit(jax.value_and_grad(_loss), static_argnums=2)(
            params, x, cfg)
        return v, g

    mesh = make_host_mesh(model=8, data=2)
    with jax.set_mesh(mesh):
        for cd in (None, jnp.bfloat16):
            tag = "bf16" if cd is not None else "fp32"
            v0, g0 = run("ring", "xla", cd)
            v1, g1 = run("ring_fused", "xla", cd)
            ok = np.array_equal(np.asarray(v0), np.asarray(v1)) and all(
                np.array_equal(np.asarray(g0[k]), np.asarray(g1[k]))
                for k in ("w", "b"))
            check(f"ring_fused == ring bit-for-bit fwd+grads ({tag})", ok)

    # pallas local GEMMs (interpret mode is slow -> 4-way mesh)
    mesh4 = make_host_mesh(model=4, data=1)
    with jax.set_mesh(mesh4):
        for cd in (None, jnp.bfloat16):
            tag = "bf16" if cd is not None else "fp32"
            v0, g0 = run("ring", "pallas", cd)
            v1, g1 = run("ring_fused", "pallas", cd)
            ok = np.array_equal(np.asarray(v0), np.asarray(v1)) and all(
                np.array_equal(np.asarray(g0[k]), np.asarray(g1[k]))
                for k in ("w", "b"))
            check(f"ring_fused == ring bit-for-bit, pallas ({tag})", ok)

    # fused transposed Cannon == dot_general lowering (token-mix path)
    wt = jax.random.normal(jax.random.PRNGKey(2), (32, 16)) * 0.1
    bt = jax.random.normal(jax.random.PRNGKey(3), (32,)) * 0.1
    mesh2 = jax.make_mesh((1, 2, 2), ("data", "mdom", "mtp"),
                          axis_types=AUTO * 3)
    with jax.set_mesh(mesh2):
        def tmix(kern, xx, ww, bb):
            y = jigsaw.jigsaw_linear_2d_t(xx, ww, bb, rules=RULES_2D,
                                          kernel=kern)
            return jnp.sum(y ** 2), y
        (_, y0), g0 = jax.jit(lambda *a: jax.value_and_grad(
            lambda xx, ww, bb: tmix("xla", xx, ww, bb), argnums=(0, 1, 2),
            has_aux=True)(*a))(x, wt, bt)
        (_, y1), g1 = jax.jit(lambda *a: jax.value_and_grad(
            lambda xx, ww, bb: tmix("pallas", xx, ww, bb),
            argnums=(0, 1, 2), has_aux=True)(*a))(x, wt, bt)
        check("2d_t cannon kernel=pallas == xla (fwd)",
              np.allclose(y0, y1, rtol=1e-5, atol=1e-5))
        check("2d_t cannon kernel=pallas == xla (grads)",
              all(np.allclose(a, b, rtol=1e-4, atol=1e-4)
                  for a, b in zip(g0, g1)))

    # VMEM-budget guard: over-budget tiles select the fallback (with the
    # one-line warning); in-budget tiles on a TPU backend select the
    # fused kernel.  backend/budget are parameters so this runs on CPU.
    import warnings as _w
    with _w.catch_warnings(record=True) as rec:
        _w.simplefilter("always")
        path = fused_ring._select_path(
            4096, 4096, 65536, 8, jnp.float32, jnp.float32,
            ("data", "model"), "model", backend="tpu", budget=1 << 20)
    check("vmem guard falls back over budget",
          path == "fallback" and any("VMEM" in str(r.message)
                                     for r in rec))
    check("vmem guard keeps the fused kernel in budget",
          fused_ring._select_path(64, 64, 128, 8, jnp.float32, jnp.float32,
                                  ("data", "model"), "model",
                                  backend="tpu") == "tpu")
    check("cpu backend always falls back",
          fused_ring._select_path(64, 64, 128, 8, jnp.float32, jnp.float32,
                                  ("data", "model"), "model") == "fallback")

    # end-to-end: 2 engine steps, fused vs monolithic ring -- identical
    # loss history bit-for-bit (every linear of the model goes through
    # the fused schedule).
    from repro.launch.engine import EngineConfig, TrainEngine

    def engine_losses(impl):
        eng = TrainEngine(
            "weathermixer-1b", mesh_model=4, mesh_data=4, scheme="1d",
            impl=impl,
            config=EngineConfig(steps=2, batch=4, log_every=1))
        eng.run()
        return [h["loss"] for h in eng.history]

    l_ring = engine_losses("ring")
    l_fused = engine_losses("ring_fused")
    check(f"engine 2-step loss history identical ({l_ring} == {l_fused})",
          np.array_equal(np.asarray(l_ring), np.asarray(l_fused)))


def scenario_zero1_engine():
    """ZeRO-1 wired into TrainEngine: loss history identical to the
    replicated-optimizer run, moments actually sharded over data (per-
    device optimizer-state bytes shrink by the data extent)."""
    from repro.launch.engine import EngineConfig, TrainEngine

    def run(zero1):
        eng = TrainEngine(
            "weathermixer-1b", mesh_model=4, mesh_data=4, scheme="1d",
            config=EngineConfig(steps=2, batch=4, log_every=1,
                                zero1=zero1))
        eng.run()
        return eng

    e0 = run(False)
    e1 = run(True)
    ok = all(np.allclose(a["loss"], b["loss"], rtol=1e-5)
             for a, b in zip(e0.history, e1.history))
    check("zero1 loss history == replicated", ok)

    def dev0_moment_bytes(eng):
        dev = jax.devices()[0]
        tot = 0
        for leaf in jax.tree.leaves({"mu": eng.opt_state["mu"],
                                     "nu": eng.opt_state["nu"]}):
            for s in leaf.addressable_shards:
                if s.device == dev:
                    tot += s.data.nbytes
        return tot

    b0, b1 = dev0_moment_bytes(e0), dev0_moment_bytes(e1)
    # data=4: every evenly divisible moment shards 4x; the residue
    # (tiny norms/biases that don't divide) keeps this from being exactly
    # 4x, but the bulk must shrink by >= 2x.
    check(f"zero1 moment bytes shrink ({b0} -> {b1})", b1 * 2 <= b0)
    spec = e1.opt_state["mu"]["blocks"]["ch_fc1"]["w"].sharding.spec
    flat = [a for e in spec if e is not None
            for a in (e if isinstance(e, tuple) else (e,))]
    check("zero1 moment spec carries the data axis", "data" in flat)


def scenario_precision_bf16():
    """Mixed-precision Jigsaw (ISSUE 5): the bf16 policy must (a) track
    the fp32 loss trajectory on the same seed within bf16 tolerance,
    (b) keep fp32 Adam master weights + moments while the donated params
    are bf16, (c) HALVE the ring/`ring_chunked` per-hop wire bytes on
    the lowered HLO, and (d) keep ring == ring_chunked bit-identical
    under the bf16 wire/f32-accum cast points."""
    import jax.numpy as jnp
    from repro.core.api import JigsawConfig, linear_apply, mlp_apply, \
        mlp_init
    from repro.launch.analysis import collective_stats
    from repro.launch.engine import EngineConfig, TrainEngine

    # --- (a)+(b): engine A/B on a 4x2 mesh -----------------------------
    def run(precision):
        eng = TrainEngine(
            "weathermixer-1b", mesh_model=4, mesh_data=2, scheme="1d",
            impl="ring_chunked",
            config=EngineConfig(steps=4, batch=4, log_every=1,
                                precision=precision))
        return eng.run(), eng

    h32, e32 = run(None)
    h16, e16 = run("bf16")
    ok = all(np.allclose(a["loss"], b["loss"], rtol=5e-2, atol=5e-3)
             for a, b in zip(h32, h16))
    check("bf16 loss history ~= fp32 (same seed)", ok)
    # losses must differ somewhere, or the bf16 path silently never ran
    check("bf16 path actually engaged (histories not bit-equal)",
          any(a["loss"] != b["loss"] for a, b in zip(h32, h16)))

    w16 = e16.params["blocks"]["ch_fc1"]["w"]
    check("params stored bf16", w16.dtype == jnp.bfloat16)
    check("Adam master weights are fp32",
          e16.opt_state["master"]["blocks"]["ch_fc1"]["w"].dtype
          == jnp.float32)
    check("Adam moments are fp32 under the bf16 policy",
          e16.opt_state["mu"]["blocks"]["ch_fc1"]["w"].dtype == jnp.float32
          and e16.opt_state["nu"]["blocks"]["ch_fc1"]["w"].dtype
          == jnp.float32)
    check("fp32 run has no master group", "master" not in e32.opt_state)

    # satellite: engine-level param PartitionSpec pinning -- without
    # zero1, params must still come back SHARDED (not GSPMD-replicated)
    spec = w16.sharding.spec
    flat = [a for e in spec if e is not None
            for a in (e if isinstance(e, tuple) else (e,))]
    check("params pinned to jigsaw specs (model axis present, "
          "non-zero1 run)", "model" in flat)

    # --- (c): ring bytes halve on the lowered HLO ----------------------
    mesh = make_host_mesh(model=4, data=1)
    params = mlp_init(jax.random.PRNGKey(0), 64, 256, 64, bias=False)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 64))
    for impl in ("ring", "ring_chunked"):
        res = {}
        for prec, cd in (("fp32", None), ("bf16", jnp.bfloat16)):
            cfg = JigsawConfig(impl=impl, compute_dtype=cd)
            with jax.set_mesh(mesh):
                low = jax.jit(
                    lambda p, v, c=cfg: mlp_apply(p, v, c)).lower(params, x)
            st = collective_stats(
                low.compiler_ir(dialect="hlo").as_hlo_text())
            res[prec] = st.total_bytes
        check(f"{impl}: bf16 wire bytes == 0.5x fp32 "
              f"({res['bf16']:.0f} vs {res['fp32']:.0f})",
              res["fp32"] > 0 and abs(res["bf16"] / res["fp32"] - 0.5)
              < 1e-6)

    # --- (d): bit-identity + accuracy of the bf16 ring -----------------
    lparams = {"w": jax.random.normal(jax.random.PRNGKey(2), (128, 64))
               * 0.1,
               "b": jax.random.normal(jax.random.PRNGKey(3), (128,)) * 0.1}
    ref = np.asarray(linear_apply(lparams, x, JigsawConfig(scheme="none")))
    with jax.set_mesh(mesh):
        outs = {}
        for impl in ("ring", "ring_chunked", "rs"):
            cfg = JigsawConfig(impl=impl, compute_dtype=jnp.bfloat16)
            outs[impl] = np.asarray(
                jax.jit(linear_apply, static_argnums=2)(lparams, x, cfg)
                .astype(jnp.float32))
        check("bf16 ring_chunked == ring bit-for-bit",
              np.array_equal(outs["ring_chunked"], outs["ring"]))
        check("bf16 ring ~= bf16 rs (wire rounding tolerance)",
              np.allclose(outs["ring_chunked"], outs["rs"], rtol=2e-2,
                          atol=2e-2))
        check("bf16 ring ~= fp32 dense reference",
              np.allclose(outs["ring_chunked"], ref, rtol=5e-2, atol=5e-2))

    # composition: bf16 x ZeRO-1 -- the fp32 masters shard over data
    # like the moments (3 fp32 trees / data-ways per rank)
    engz = TrainEngine(
        "weathermixer-1b", mesh_model=4, mesh_data=2, scheme="1d",
        config=EngineConfig(steps=2, batch=4, log_every=1,
                            precision="bf16", zero1=True))
    hz = engz.run()
    mspec = engz.opt_state["master"]["blocks"]["ch_fc1"]["w"].sharding.spec
    mflat = [a for e in mspec if e is not None
             for a in (e if isinstance(e, tuple) else (e,))]
    check("bf16 x zero1: master weights sharded over data",
          "data" in mflat)
    check("bf16 x zero1: loss tracks the non-zero1 bf16 run",
          np.allclose(hz[0]["loss"], h16[0]["loss"], rtol=1e-3))

    # bf16_pure: memory-minimal -- bf16 moments, no masters
    engp = TrainEngine(
        "weathermixer-1b", mesh_model=4, mesh_data=2, scheme="1d",
        config=EngineConfig(steps=2, batch=4, log_every=1,
                            precision="bf16_pure"))
    engp.run()
    check("bf16_pure: no master group", "master" not in engp.opt_state)
    check("bf16_pure: bf16 moments",
          engp.opt_state["mu"]["blocks"]["ch_fc1"]["w"].dtype
          == jnp.bfloat16)


def scenario_ring_collectives():
    """Explicit ring reduce-scatter / allgather == native collectives."""
    mesh = make_host_mesh(model=8, data=2)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))

    def rs(v):
        return jigsaw.ring_reduce_scatter(v, "model", 8)

    def ag(v):
        return jigsaw.ring_all_gather(v, "model", 8, gather_dim=-1)

    with jax.set_mesh(mesh):
        out = jax.jit(jax.shard_map(
            rs, mesh=mesh, in_specs=P(None, None),
            out_specs=P(None, "model"), axis_names={"model"},
            check_vma=False))(x)
        check("ring_reduce_scatter == 8*chunk",
              np.allclose(out, 8 * x, rtol=1e-5))
        out2 = jax.jit(jax.shard_map(
            ag, mesh=mesh, in_specs=P(None, "model"),
            out_specs=P(None, None), axis_names={"model"},
            check_vma=False))(x)
        check("ring_all_gather roundtrip", np.allclose(out2, x, rtol=1e-6))


def scenario_weathermixer_schemes():
    """WM forward under 1d and 2d Jigsaw == dense (paper Fig. 4)."""
    from repro.configs.registry import get_config
    from repro.models import registry as M
    from repro.launch import shapes as SH

    cfg0 = get_config("weathermixer-1b").reduced()
    key = jax.random.PRNGKey(0)
    params = M.init(key, cfg0)
    batch = {"fields": jax.random.normal(key, (4, cfg0.wm_lat, cfg0.wm_lon,
                                               cfg0.wm_channels))}
    ref, _ = M.apply(params, batch, cfg0, SH.jigsaw_for(cfg0))

    mesh1 = make_host_mesh(model=4, data=4)
    cfg1 = cfg0.replace(scheme="1d")
    with jax.set_mesh(mesh1):
        out1, _ = jax.jit(lambda p, b: M.apply(p, b, cfg1,
                                               SH.jigsaw_for(cfg1)))(
            params, batch)
    check("WM 1d (2-way generalized) == dense",
          np.allclose(out1, ref, rtol=1e-3, atol=1e-4))

    mesh2 = make_host_mesh(model=4, data=1, two_d=True)
    cfg2 = cfg0.replace(scheme="2d")
    with jax.set_mesh(mesh2):
        out2, _ = jax.jit(lambda p, b: M.apply(p, b, cfg2,
                                               SH.jigsaw_for(cfg2)))(
            params, batch)
    check("WM 2d (4-way Cannon) == dense",
          np.allclose(out2, ref, rtol=1e-3, atol=1e-4))


def scenario_transformer_1d():
    """Reduced internlm2 forward under 1-D Jigsaw mesh == dense."""
    from repro.configs.registry import get_config
    from repro.models import registry as M
    from repro.launch import shapes as SH

    cfg0 = get_config("internlm2-1.8b").reduced()
    key = jax.random.PRNGKey(0)
    params = M.init(key, cfg0)
    batch = {"tokens": jax.random.randint(key, (4, 32), 0,
                                          cfg0.vocab_size)}
    ref, _ = M.apply(params, batch, cfg0, SH.jigsaw_for(cfg0))
    mesh = make_host_mesh(model=4, data=4)
    cfg = cfg0.replace(scheme="1d", impl="rs")
    with jax.set_mesh(mesh):
        out, _ = jax.jit(lambda p, b: M.apply(p, b, cfg,
                                              SH.jigsaw_for(cfg)))(
            params, batch)
    check("transformer 1d jigsaw == dense",
          np.allclose(out, ref, rtol=1e-3, atol=1e-3))


def scenario_train_step_mesh():
    """One full train step on a mesh == same step on one device."""
    from repro.configs.registry import get_config
    from repro.models import registry as M
    from repro.launch import shapes as SH
    from repro.optim import adam
    from repro.train.step import make_train_step

    cfg0 = get_config("stablelm-3b").reduced()
    key = jax.random.PRNGKey(0)
    params = M.init(key, cfg0)
    acfg = adam.AdamConfig()
    opt = adam.init(params, acfg)
    batch = {"tokens": jax.random.randint(key, (8, 16), 0, cfg0.vocab_size),
             "labels": jax.random.randint(key, (8, 16), 0, cfg0.vocab_size)}
    p_ref, _, m_ref = make_train_step(cfg0, SH.jigsaw_for(cfg0), acfg)(
        params, opt, batch)
    cfg = cfg0.replace(scheme="1d")
    mesh = make_host_mesh(model=4, data=2)
    with jax.set_mesh(mesh):
        p_new, _, m_new = jax.jit(make_train_step(cfg, SH.jigsaw_for(cfg),
                                                  acfg))(params, opt, batch)
    check("train-step loss on mesh == dense",
          np.allclose(m_new["loss"], m_ref["loss"], rtol=1e-4))
    flat_ref = jax.tree.leaves(p_ref)
    flat_new = jax.tree.leaves(p_new)
    ok = all(np.allclose(a, b, rtol=1e-3, atol=1e-4)
             for a, b in zip(flat_ref, flat_new))
    check("train-step params on mesh == dense", ok)


def scenario_input_pipeline():
    """Domain-parallel sharded reads == sync-full batches bit-for-bit on
    1-d and 2-d meshes (horizon > 1 included), per-rank generated bytes
    shrink ∝ 1/(model-parallel ranks), and the measured per-rank bytes
    match the dataset's io_bytes_per_rank model (paper §5)."""
    from repro.configs.registry import get_config
    from repro.core.sharding import RULES_1D, RULES_2D
    from repro.data.pipeline import make_pipeline

    cfg = get_config("weathermixer-1b").reduced().replace(scheme="1d")
    bsz = 4

    def pipes(mesh, rules, mode, prefetch=0):
        return make_pipeline(cfg, mesh=mesh, rules=rules, batch_size=bsz,
                             mode=mode, prefetch=prefetch)

    # --- sharded == sync-full, bit for bit (1d mesh, horizons 1 and 3)
    mesh = make_host_mesh(model=4, data=4)
    for horizon in (1, 3):
        a = pipes(mesh, RULES_1D, "sharded").get(5, horizon)
        b = pipes(mesh, RULES_1D, "sync-full").get(5, horizon)
        for k in a:
            check(f"1d sharded == sync key={k} horizon={horizon}",
                  np.array_equal(np.asarray(a[k]), np.asarray(b[k])))

    # --- 2-d mesh (lon over mdom, channels over mtp)
    cfg2 = cfg.replace(scheme="2d")
    mesh2 = make_host_mesh(model=4, data=4, two_d=True)
    a = make_pipeline(cfg2, mesh=mesh2, rules=RULES_2D, batch_size=bsz,
                      mode="sharded", prefetch=0).get(3, 2)
    b = make_pipeline(cfg2, mesh=mesh2, rules=RULES_2D, batch_size=bsz,
                      mode="sync-full", prefetch=0).get(3, 2)
    for k in a:
        check(f"2d sharded == sync key={k}",
              np.array_equal(np.asarray(a[k]), np.asarray(b[k])))

    # --- LM token rows (per-data-rank reads)
    lcfg = get_config("internlm2-1.8b").reduced().replace(scheme="1d")
    lm = make_pipeline(lcfg, mesh=mesh, rules=RULES_1D, batch_size=8,
                       seq_len=32, mode="sharded", prefetch=0).get(1)
    lm2 = make_pipeline(lcfg, mesh=mesh, rules=RULES_1D, batch_size=8,
                        seq_len=32, mode="sync-full", prefetch=0).get(1)
    for k in lm:
        check(f"lm sharded == sync key={k}",
              np.array_equal(np.asarray(lm[k]), np.asarray(lm2[k])))

    # --- per-rank bytes ∝ 1/(model ranks), == the io model
    devs = jax.devices()
    full_bytes = 4 * bsz * cfg.wm_lat * cfg.wm_lon * cfg.wm_channels
    per_rank = {}
    for ways in (2, 4, 8):
        m = jax.make_mesh((1, ways), ("data", "model"),
                          devices=devs[:ways])
        p = pipes(m, RULES_1D, "sharded")
        p.get(0)
        ranks = p.stats.rank_bytes["fields"]
        per_rank[ways] = max(ranks.values())
        check(f"{ways}-way ranks uniform", len(set(ranks.values())) == 1)
        check(f"{ways}-way per-rank == io model",
              per_rank[ways] == p.io_bytes_per_rank(ways)
              == full_bytes // ways)
    check("per-rank bytes ∝ 1/ranks",
          per_rank[2] == 2 * per_rank[4] == 4 * per_rank[8])

    # --- prefetcher determinism: same seed => same batches as sync
    sync = pipes(mesh, RULES_1D, "sharded", prefetch=0)
    pref = pipes(mesh, RULES_1D, "sharded", prefetch=2)
    horizons = [1, 2, 1, 3]
    got = list(pref.iterate(horizons))
    want = [sync.get(i, h) for i, h in enumerate(horizons)]
    ok = all(np.array_equal(np.asarray(g[k]), np.asarray(w[k]))
             for g, w in zip(got, want) for k in g)
    check("prefetch thread == synchronous reads", ok)

    # --- per-HOST read dedup (ROADMAP follow-up): tokens are replicated
    # over the 4-way model axis, but each row group must be generated
    # once per host, not once per addressable device -- and the read
    # plan is built once, not per step.
    lp = make_pipeline(lcfg, mesh=mesh, rules=RULES_1D, batch_size=8,
                       seq_len=32, mode="sharded", prefetch=0)
    for s in range(3):
        lp.get(s)
    tok_bytes = 8 * 32 * np.dtype(np.int32).itemsize
    check("replicated tokens generated once per host per step",
          lp.stats.generated_bytes["tokens"] == 3 * tok_bytes)
    check("every model-replica rank still accounts its read",
          sum(lp.stats.rank_bytes["tokens"].values()) == 3 * 4 * tok_bytes)
    check("read plan built once per key (not per step)",
          lp.stats.plan_builds == len(lp.source.keys))


def scenario_engine_pipeline():
    """TrainEngine on a mesh: sharded+prefetch reproduces sync-full loss
    curves exactly (same seed), incl. randomized rollout; microbatch
    accumulation matches the full-batch step within fp tolerance."""
    from repro.launch.engine import EngineConfig, TrainEngine

    def run(mode, prefetch, accum=1, steps=4):
        eng = TrainEngine(
            "weathermixer-1b", mesh_model=4, mesh_data=2, scheme="1d",
            config=EngineConfig(steps=steps, batch=4, rollout=2,
                                log_every=steps - 1, pipeline=mode,
                                prefetch=prefetch, accum=accum))
        return eng.run(), eng

    h_sync, _ = run("sync-full", 0)
    h_shard, eng = run("sharded", 2)
    ok = all(np.allclose(a["loss"], b["loss"], rtol=1e-6)
             and np.allclose(a["grad_norm"], b["grad_norm"], rtol=1e-5)
             for a, b in zip(h_sync, h_shard))
    check("engine sharded+prefetch == sync-full history", ok)

    em = eng.evaluate(n_batches=1)
    check("engine eval on mesh", np.isfinite(em["val_loss"]))

    h_acc, _ = run("sharded", 2, accum=2, steps=2)
    h_one, _ = run("sharded", 2, accum=1, steps=2)
    check("accum=2 step ~= accum=1 step",
          np.allclose(h_acc[0]["loss"], h_one[0]["loss"], rtol=1e-5))


def scenario_ckpt_sharded_reshard():
    """Zero-redundancy sharded checkpointing (ISSUE 4): saving a
    jigsaw + ZeRO-1 sharded model writes only each rank's addressable
    shards (per-rank byte accounting ~= total_bytes / n_ranks, summed
    exactly to the deduplicated total -- i.e. no full-model gather
    anywhere), and restore is topology-free: the same checkpoint lands
    bit-identically under a DIFFERENT mesh (8-way ring saved, 4-way
    restored), under explicit spec overrides, and as plain numpy."""
    import tempfile

    from repro.checkpoint import sharded
    from repro.configs.registry import get_config
    from repro.launch import specs as S
    from repro.models import registry as M
    from repro.optim import adam

    cfg = get_config("weathermixer-1b").reduced().replace(scheme="1d")
    mesh = make_host_mesh(model=8, data=2)
    params = M.init(jax.random.PRNGKey(0), cfg)
    pspecs = S.sanitize_tree(
        params, S.param_specs(params, cfg, RULES_1D, mesh), mesh)
    params = jax.device_put(params, S.to_shardings(pspecs, mesh))
    opt = adam.init(params, adam.AdamConfig())
    ospecs = S.sanitize_tree(
        opt, S.opt_specs(opt["mu"], pspecs, zero1_axis="data", mesh=mesh),
        mesh)
    opt = jax.device_put(opt, S.to_shardings(ospecs, mesh))

    total = sum(l.nbytes for l in jax.tree.leaves([params, opt]))
    path = os.path.join(tempfile.mkdtemp(), "ck")
    snap = sharded.save_checkpoint(
        path, {"params": params, "opt_state": opt}, step=7,
        extra={"scheme": "1d"})
    n = len(jax.devices())
    check(f"sharded save writes total bytes exactly once "
          f"({snap.total_bytes} == {total})", snap.total_bytes == total)
    per_rank = max(snap.bytes_per_rank.values())
    check(f"per-rank bytes ~= total/n_ranks ({per_rank} vs "
          f"{total // n})", per_rank <= 2 * total // n)
    check("every rank writes something",
          len(snap.bytes_per_rank) == n
          and min(snap.bytes_per_rank.values()) > 0)

    def same(tree_a, tree_b):
        return all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(jax.tree.leaves(tree_a),
                                   jax.tree.leaves(tree_b)))

    # restore under a DIFFERENT topology (8-way ring -> 4-way)
    mesh4 = make_host_mesh(model=4, data=2)
    got = sharded.restore_tree(path, "params", like=params, mesh=mesh4)
    check("resharded restore (8-way -> 4-way) bit-identical",
          same(got, params))
    w = got["blocks"]["ch_fc1"]["w"]
    check("restored leaves actually live on the 4-way mesh",
          dict(w.sharding.mesh.shape) == {"data": 2, "model": 4}
          and "model" in tuple(w.sharding.spec))

    # explicit spec override beats the saved spec
    got2 = sharded.restore_tree(
        path, "params", mesh=mesh4,
        specs={"blocks": {"ch_fc1": {"w": P(None, None, "model")}}})
    check("spec-override restore bit-identical", same(got2, params))

    # host-side restore (no mesh): plain numpy, still validated
    npy = sharded.restore_tree(path, "opt_state", like=opt)
    check("numpy restore bit-identical (opt_state incl. zero1 moments)",
          same(npy, opt))

    # restore on the SAME topology keeps the saved zero1 layout
    same_mesh = sharded.restore_tree(path, "opt_state", mesh=mesh)
    mu = same_mesh["mu"]["blocks"]["ch_fc1"]["w"]
    flat_axes = [a for e in mu.sharding.spec if e is not None
                 for a in (e if isinstance(e, tuple) else (e,))]
    check("same-topology restore keeps the zero1 data-axis shard",
          "data" in flat_axes)


def scenario_resume_exact():
    """Exact-resume (ISSUE 4): a run interrupted at step k and resumed
    from its sharded checkpoint reproduces the uninterrupted loss
    history BIT-FOR-BIT (params, Adam state incl. step, rollout
    schedule, and the data-pipeline cursor all restored), on a mesh,
    with ZeRO-1 moments and the async writer in the loop."""
    import tempfile

    from repro.launch.engine import EngineConfig, TrainEngine

    path = os.path.join(tempfile.mkdtemp(), "ck")

    def engine(**kw):
        return TrainEngine(
            "weathermixer-1b", mesh_model=4, mesh_data=2, scheme="1d",
            config=EngineConfig(steps=6, batch=4, rollout=2, zero1=True,
                                log_every=1, pipeline="sharded",
                                prefetch=2, **kw))

    full = engine()
    h_full = full.run()

    # "interrupted" run: async checkpoint at step 4 (loop index 3),
    # then the process goes away
    interrupted = engine(ckpt=path, ckpt_every=3)
    interrupted.run()
    check("interrupted run checkpointed mid-flight (async writer)",
          interrupted.last_save is not None
          and os.path.exists(os.path.join(path + "-3", "manifest.json")))
    per = interrupted.last_save.bytes_per_rank
    total = interrupted.last_save.total_bytes
    n_mesh = interrupted.mesh.devices.size
    check(f"engine save is sharded, not gathered (max rank "
          f"{max(per.values())} of {total})",
          max(per.values()) <= 2 * total // n_mesh)

    resumed = engine(resume=path + "-3")
    check("resume restored the step index", resumed.step_idx == 4)
    check("resume restored the pipeline cursor",
          resumed.pipeline.cursor == 4)
    h_res = resumed.run()

    tail = [h for h in h_full if h["step"] >= 4]
    check("resumed history length", len(h_res) == len(tail) == 2)
    ok = all(a["loss"] == b["loss"] and a["lr"] == b["lr"]
             and a["grad_norm"] == b["grad_norm"]
             for a, b in zip(tail, h_res))
    check("interrupted-at-k + resume == uninterrupted history "
          "(bit-for-bit)", ok)


def scenario_preempt_resume_exact():
    """Fault tolerance end-to-end (ISSUE 7): a REAL SIGTERM mid-run (the
    chaos hook self-delivers it after step 3), the child finishes the
    in-flight step, takes a final synchronous save, exits the resumable
    code; the Supervisor rediscovers the checkpoint and relaunches with
    ``--resume``; the concatenated loss history of the two child
    processes is BIT-IDENTICAL to an uninterrupted in-process run on the
    same seed."""
    import json
    import tempfile

    from repro.launch import resilience
    from repro.launch.engine import EngineConfig, TrainEngine

    steps, kill_at = 8, 3
    root = tempfile.mkdtemp()

    # uninterrupted in-process reference
    ref = TrainEngine(
        "weathermixer-1b", mesh_model=4, mesh_data=2, scheme="1d",
        config=EngineConfig(steps=steps, batch=4, rollout=2, zero1=True,
                            log_every=1))
    h_ref = ref.run()

    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep \
        + env.get("PYTHONPATH", "")
    # the chaos hook: attempt 0's loop hits i==3 and self-SIGTERMs; the
    # resumed child starts at i==4, so the SAME env never re-fires
    env[resilience.PREEMPT_ENV] = str(kill_at)

    def build(resume, attempt):
        cmd = [sys.executable, "-m", "repro.launch.train",
               "--arch", "weathermixer-1b", "--steps", str(steps),
               "--batch", "4", "--rollout", "2", "--zero1",
               "--mesh-model", "4", "--mesh-data", "2", "--scheme", "1d",
               "--log-every", "1", "--ckpt", os.path.join(root, "ck"),
               "--metrics-out", os.path.join(root, f"m{attempt}.json")]
        if resume:
            cmd += ["--resume", resume]
        return cmd

    sup = resilience.Supervisor(build, ckpt_root=root, prefix="ck",
                                max_restarts=3, env=env)
    rc = sup.run()
    check(f"supervised run finished clean (rc={rc})", rc == 0)
    check(f"attempt exit codes {sup.attempts} == "
          f"[{resilience.RESUMABLE_EXIT_CODE}, 0]",
          sup.attempts == [resilience.RESUMABLE_EXIT_CODE, 0])
    check("relaunch resumed from the preemption checkpoint",
          sup.resumes[0] is None and sup.resumes[1] is not None
          and sup.resumes[1].endswith(f"ck-{kill_at}"))
    check("resumable exit relaunched immediately (no backoff)",
          sup.backoffs == [])

    with open(os.path.join(root, "m0.json")) as f:
        h0 = [json.loads(line) for line in f if line.strip()]
    with open(os.path.join(root, "m1.json")) as f:
        h1 = [json.loads(line) for line in f if line.strip()]
    check(f"first child logged steps 0..{kill_at}",
          [h["step"] for h in h0] == list(range(kill_at + 1)))
    check(f"second child logged steps {kill_at + 1}..{steps - 1}",
          [h["step"] for h in h1] == list(range(kill_at + 1, steps)))
    h_cat = h0 + h1
    ok = all(a["loss"] == b["loss"] and a["lr"] == b["lr"]
             and a["grad_norm"] == b["grad_norm"]
             for a, b in zip(h_ref, h_cat))
    check("SIGTERM + supervisor restart == uninterrupted history "
          "(bit-for-bit)", ok)


def scenario_elastic_reshard_resume():
    """Elastic resume (ISSUE 7): a ZeRO-1 run checkpointed on an 8-device
    mesh (model=4 x data=2) resumes on a 4-device mesh (model=2 x
    data=2) -- the engine refits params AND the zero1 moment/master
    layouts to the new mesh -- with loss continuity, and a save from the
    resumed engine shards bytes across the 4 survivors.  Plus the
    pod-scale completeness contract: per-process index fragments, rank-0
    merge, and a half-written pod save that stays invisible to
    ``latest_checkpoint``."""
    import tempfile

    from repro.checkpoint import sharded
    from repro.launch.engine import EngineConfig, TrainEngine

    root = tempfile.mkdtemp()
    path = os.path.join(root, "ck")

    def engine(mesh_model, mesh_data, **kw):
        return TrainEngine(
            "weathermixer-1b", mesh_model=mesh_model, mesh_data=mesh_data,
            scheme="1d",
            config=EngineConfig(steps=6, batch=4, zero1=True,
                                log_every=1, **kw))

    # the "big" run: 8 devices, periodic save at loop index 3 (step 4)
    big = engine(4, 2, ckpt=path, ckpt_every=3)
    h_big = big.run()
    ck = f"{path}-3"
    check("8-way run checkpointed mid-flight",
          sharded.checkpoint_complete(ck))
    check("latest_checkpoint picks the final (higher-step) save",
          sharded.latest_checkpoint(root, prefix="ck") == path)

    # resume on HALF the devices
    small = engine(2, 2, resume=ck)
    check("elastic resume restored the step index", small.step_idx == 4)
    check("elastic resume restored the pipeline cursor",
          small.pipeline.cursor == 4)
    mu = small.opt_state["mu"]["blocks"]["ch_fc1"]["w"]
    check("restored zero1 moments live on the 4-device mesh",
          dict(mu.sharding.mesh.shape) == {"data": 2, "model": 2})
    flat = [a for e in mu.sharding.spec if e is not None
            for a in (e if isinstance(e, tuple) else (e,))]
    check("zero1 moment layout refit to the new mesh (data axis kept)",
          "data" in flat)

    h_small = small.run()
    tail = [h for h in h_big if h["step"] >= 4 and "eval" not in h]
    check("resumed history length", len(h_small) == len(tail) == 2)
    ok = all(np.allclose(a["loss"], b["loss"], rtol=1e-3, atol=1e-4)
             for a, b in zip(tail, h_small))
    check("8-way -> 4-way loss continuity (fp tolerance: reduction "
          "order differs across mesh extents)", ok)

    # byte accounting on the resumed topology: a fresh save spreads the
    # bytes over the 4 surviving devices
    small.save(os.path.join(root, "ck-resharded"), block=True)
    per = small.last_save.bytes_per_rank
    total = small.last_save.total_bytes
    check(f"resharded save is sharded over the survivors "
          f"(max rank {max(per.values())} of {total})",
          len(per) == 4 and max(per.values()) <= 2 * total // 4)

    # ---- pod-scale completeness: per-process indexes + rank-0 merge ----
    snap = sharded.snapshot(
        {"params": big.params, "opt_state": big.opt_state},
        step=big.step_idx, mesh=big.mesh)
    assign = {d: (0 if i < 4 else 1)
              for i, d in enumerate(sorted(snap.bytes_per_rank))}
    frags = sharded.partition_snapshot(snap, assign)
    check("partition splits the byte accounting exactly",
          sum(sum(f.bytes_per_rank.values()) for f in frags.values())
          == snap.total_bytes)

    pod = os.path.join(root, "pod")
    # process 1 lands first: shards + index fragment, NO manifest yet
    sharded.write_snapshot(frags[1], pod, process_index=1,
                           process_count=2)
    check("half-written pod save is incomplete (no manifest)",
          not sharded.checkpoint_complete(pod))
    check("half-written pod save invisible to latest_checkpoint",
          sharded.latest_checkpoint(root, prefix="pod") is None)
    # process 0 lands: writes its shards, merges, publishes the manifest
    sharded.write_snapshot(frags[0], pod, process_index=0,
                           process_count=2)
    check("finalized pod save is complete",
          sharded.checkpoint_complete(pod)
          and sharded.latest_checkpoint(root, prefix="pod") == pod)
    got = sharded.restore_tree(pod, "params")
    want = sharded.restore_tree(path, "params")
    ok = all(np.array_equal(np.asarray(a), np.asarray(b))
             for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)))
    check("pod-save restore bit-identical to the single-process save",
          ok)


def scenario_serving_restore():
    """Serving restore (ISSUE 8): an 8-way (model=4 x data=2) sharded
    training checkpoint's params group lands on 1-, 2-, 4- and 8-way
    DATA-ONLY serving meshes; fp32 rollouts through the ForecastEngine
    are BIT-identical across every serving shape (and to the plain
    numpy single-device restore), and a bf16-policy checkpoint serves
    both natively (bf16) and cast to fp32 on restore."""
    import tempfile

    from repro.checkpoint.serving import restore_serving_params
    from repro.data.weather import WeatherDataConfig, WeatherDataset
    from repro.launch.engine import EngineConfig, TrainEngine
    from repro.models import registry as M
    from repro.serve.engine import ForecastEngine, ServeConfig

    root = tempfile.mkdtemp()
    cks = {}
    for prec in (None, "bf16"):
        tag = prec or "fp32"
        path = os.path.join(root, f"ck-{tag}")
        eng = TrainEngine("weathermixer-1b", mesh_model=4, mesh_data=2,
                          scheme="1d",
                          config=EngineConfig(steps=3, batch=4,
                                              precision=prec,
                                              log_every=10))
        eng.run()
        eng.save(path, block=True)
        cks[tag] = path

    cfg = ForecastEngine("weathermixer-1b").cfg   # reduced serving config
    ds = WeatherDataset(WeatherDataConfig(
        lat=cfg.wm_lat, lon=cfg.wm_lon, channels=cfg.wm_channels, seed=3))
    fields = ds.sample_batch(0, 5)["fields"]
    leads = [1, 2, 3, 2, 1]

    outs = {}
    for nd in (1, 2, 4, 8):
        se = ForecastEngine("weathermixer-1b", ckpt=cks["fp32"],
                            mesh_data=nd,
                            config=ServeConfig(buckets=(2, 4)))
        res = se.serve(fields, leads)
        check(f"fp32 restore on data={nd} serves every request",
              all(r.done() for r in res))
        outs[nd] = np.stack([r.result() for r in res])
    for nd in (2, 4, 8):
        check(f"fp32 rollouts bit-identical: serving data={nd} == data=1",
              np.array_equal(outs[nd], outs[1]))

    # ground truth: plain numpy restore, hand-rolled rollout, no engine
    np_params, man = restore_serving_params(cks["fp32"], arch="weathermixer-1b")
    check("manifest carries training metadata (precision, step)",
          man.extra.get("precision") in ("fp32", "legacy")
          and man.step >= 1)
    se1 = ForecastEngine("weathermixer-1b", params=np_params)
    ref = []
    for f, ld in zip(fields, leads):
        x = jnp.asarray(f[None])
        for _ in range(ld):
            x = M.forecast_step(se1.params, x, se1.cfg, se1.jcfg)
        ref.append(np.asarray(x[0]))
    # eager op-by-op vs the engine's jitted padded-batch step: XLA fuses
    # differently, so this reference is tolerance-level (the bitwise
    # guarantee above is across serving MESH SHAPES, all jitted)
    check("engine rollouts match the hand-rolled numpy restore",
          np.allclose(outs[1], np.stack(ref), rtol=1e-5, atol=1e-5))

    # bf16 checkpoint: native bf16 serving and fp32-cast serving
    outs16 = {}
    for prec in ("bf16", "fp32"):
        se = ForecastEngine("weathermixer-1b", ckpt=cks["bf16"],
                            mesh_data=4,
                            config=ServeConfig(buckets=(2, 4),
                                               precision=prec))
        w = se.params["encoder"]["w"]
        want = jnp.bfloat16 if prec == "bf16" else jnp.float32
        check(f"bf16 ckpt served at {prec}: weights are {want.__name__}",
              w.dtype == want)
        res = se.serve(fields, leads)
        outs16[prec] = np.stack([np.asarray(r.result(), np.float32)
                                 for r in res])
    check("bf16 vs fp32-cast serving of the same ckpt agree loosely",
          np.allclose(outs16["bf16"], outs16["fp32"], rtol=0.1, atol=0.1))


def scenario_telemetry_trace():
    """Unified telemetry end-to-end (ISSUE 9): an instrumented wm-1b
    training run on a 4x2 mesh produces (a) a Perfetto-loadable Chrome
    trace whose dispatch / eval / ckpt_submit spans nest inside their
    step span and whose pipeline.produce spans live on the prefetch
    thread's track, (b) a JSONL whose per-step records carry finite
    mfu / comm_fraction / achieved_tflops consistent with the analytic
    cost model, and (c) an HLO collective-byte count that cross-checks
    the analytic wire model to within a small factor."""
    import json
    import math
    import tempfile

    from repro import telemetry
    from repro.launch.engine import EngineConfig, TrainEngine
    from repro.launch import trace_report

    root = tempfile.mkdtemp()
    trace = os.path.join(root, "run.trace.json")
    eng = TrainEngine(
        "weathermixer-1b", mesh_model=4, mesh_data=2, scheme="1d",
        config=EngineConfig(steps=6, batch=4, log_every=2,
                            ckpt=os.path.join(root, "ck"), ckpt_every=2,
                            trace=trace))
    eng.run()

    # -- Chrome trace: schema + nesting --------------------------------
    with open(trace) as f:
        doc = json.load(f)
    evs = doc["traceEvents"]
    xs = [e for e in evs if e.get("ph") == "X"]
    names = {e["name"] for e in xs}
    check(f"trace has the span taxonomy ({sorted(names)})",
          {"data_wait", "step", "dispatch", "ckpt_submit",
           "pipeline.produce", "ckpt.write"} <= names)

    def within(child, parent):
        return (parent["ts"] <= child["ts"] and
                child["ts"] + child["dur"]
                <= parent["ts"] + parent["dur"] + 1e-3)

    steps = [e for e in xs if e["name"] == "step"]
    check("one step span per training step", len(steps) == 6)

    def enclosing_step(e):
        return any(p["tid"] == e["tid"] and within(e, p) for p in steps)

    disp = [e for e in xs if e["name"] == "dispatch"]
    check("every dispatch span nests inside a step span",
          len(disp) == 6 and all(enclosing_step(e) for e in disp))
    subs = [e for e in xs if e["name"] == "ckpt_submit"]
    check("periodic ckpt_submit spans nest inside their step span "
          "(final save is outside the loop)",
          sum(enclosing_step(e) for e in subs) >= 2)
    main_tid = steps[0]["tid"]
    prod = [e for e in xs if e["name"] == "pipeline.produce"]
    check("pipeline.produce spans run on the prefetch thread's track",
          prod and all(e["tid"] != main_tid for e in prod))
    wr = [e for e in xs if e["name"] == "ckpt.write"]
    check("ckpt.write spans run off the main thread (async writer)",
          wr and all(e["tid"] != main_tid for e in wr))

    # -- JSONL: finite derived metrics + trace_report ------------------
    jpath = telemetry.jsonl_path_for(trace)
    meta, srecs, *_ = trace_report.split_records(
        trace_report.load_records(jpath))
    check("trace JSONL parses with 6 step records", len(srecs) == 6)
    check("trace-report --check passes (finite mfu/comm_fraction)",
          trace_report.check(meta, srecs) == [])
    cm = eng.cost_model
    ok_cons = True
    for s in srecs:
        want = cm.metrics(s["dur_s"], rollout=s["rollout"])
        for k, v in want.items():
            ok_cons &= math.isclose(s[k], v, rel_tol=0.05)
    check("JSONL mfu/comm_fraction/achieved_tflops match the analytic "
          "model (±5%)", ok_cons)
    att = trace_report.attribution(meta, srecs)
    check("roofline attribution renders a verdict",
          att is not None and "bound" in trace_report.verdict(att))

    # -- HLO cross-check: analytic wire bytes vs compiled collectives --
    # model-only mesh (no data axis): the analytic model counts ONLY
    # jigsaw mixer traffic, so a data-axis grad all-reduce would swamp
    # the comparison
    eng1 = TrainEngine("weathermixer-1b", mesh_model=4, mesh_data=1,
                       scheme="1d",
                       config=EngineConfig(steps=1, batch=4))
    with eng1._mesh_ctx():
        batch = eng1.pipeline.get(0, 1)
        compiled = eng1.step_fns[1].lower(
            eng1.params, eng1.opt_state, batch).compile()
    measured = telemetry.hlo_collective_bytes(compiled)
    model = eng1.cost_model.comm_bytes_per_device
    ratio = measured / model
    check(f"HLO collective bytes within 4x of the analytic wire model "
          f"(measured {measured:.3g}, model {model:.3g}, "
          f"ratio {ratio:.2f})", 0.25 <= ratio <= 4.0)


SCENARIOS = {name[len("scenario_"):]: fn
             for name, fn in list(globals().items())
             if name.startswith("scenario_")}


def main():
    names = sys.argv[1:] or list(SCENARIOS)
    for n in names:
        print(f"[scenario] {n}")
        SCENARIOS[n]()
    print("ALL-OK")


if __name__ == "__main__":
    main()
