"""WeatherMixer model invariants (paper §3/§5/§6)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.launch import shapes as SH
from repro.models import registry as M
from repro.models import weathermixer as WM

KEY = jax.random.PRNGKey(0)
CFG = get_config("weathermixer-1b").reduced()


def test_patchify_roundtrip():
    x = jax.random.normal(KEY, (2, 16, 24, 5))
    p = WM.patchify(x, 4)
    assert p.shape == (2, (16 // 4) * (24 // 4), 4 * 4 * 5)
    back = WM.unpatchify(p, 16, 24, 4, 5)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_blend_initialized_balanced():
    """blend starts at sigmoid(0)=0.5: forecast = (input + pred)/2."""
    params = M.init(KEY, CFG)
    batch = {"fields": jax.random.normal(KEY, (2, CFG.wm_lat, CFG.wm_lon,
                                               CFG.wm_channels))}
    jcfg = SH.jigsaw_for(CFG)
    out, _ = M.apply(params, batch, CFG, jcfg)
    h = WM.patchify(batch["fields"], CFG.wm_patch)
    # identical formula with lam = 0.5
    assert out.shape == batch["fields"].shape


def test_rollout_composes_processor():
    """rollout=r == manually looping the processor r times."""
    params = M.init(KEY, CFG)
    jcfg = SH.jigsaw_for(CFG)
    x = jax.random.normal(KEY, (2, WM.n_tokens(CFG), CFG.d_model)) * 0.1
    one = WM.processor(params, x, CFG, jcfg, rollout=1)
    two_manual = WM.processor(params, one, CFG, jcfg, rollout=1)
    two = WM.processor(params, x, CFG, jcfg, rollout=2)
    np.testing.assert_allclose(np.asarray(two), np.asarray(two_manual),
                               rtol=1e-4, atol=1e-5)


def test_paper_zoo_configs():
    """Table 1 zoo: dims match the paper's table."""
    from repro.configs.weathermixer_1b import ZOO
    assert ZOO[7].d_model == 4896 and ZOO[7].wm_d_tok == 8640
    assert ZOO[1].d_model == 240 and ZOO[1].wm_d_tok == 540
    # param counts roughly match the paper's "Params (mil)" column
    # (model 7: 1400M; model 5: 500M -- the paper rounds)
    # our exact accounting gives ~1.04B for model 7; the paper's table
    # says 1,400M ("roughly increased linearly" -- their own rounding)
    p7 = ZOO[7].param_count() / 1e6
    assert 900 < p7 < 1700, p7
    p5 = ZOO[5].param_count() / 1e6
    assert 380 < p5 < 650, p5


def test_training_loss_decreases():
    from repro.launch.train import train
    hist, _ = train("weathermixer-1b", steps=30, batch=4, reduced=True,
                    log_every=29, lr=2e-3)
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.7, hist
