"""Sharded checkpoint subsystem (DESIGN.md §9): manifest layout,
save/restore round-trips, leaf validation with key paths, cross-shard
slice reassembly, the async writer (overlap + in-flight guard + error
propagation), pipeline cursor state, and single-device exact resume.
Multi-device save/reshard/resume runs as dist scenarios
(``ckpt_sharded_reshard`` here via subprocess; ``resume_exact`` via
test_distributed.py)."""
import json
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import io as ckpt_io
from repro.checkpoint import manifest as MF
from repro.checkpoint import sharded
from repro.checkpoint.writer import AsyncCheckpointWriter
from repro.optim import adam

HERE = os.path.dirname(__file__)


def _params():
    return {"layer": {"w": jnp.arange(12.0).reshape(3, 4),
                      "b": jnp.zeros((4,), jnp.float32)},
            "embed": {"table": jnp.ones((4, 2))},
            "blend": jnp.arange(3, dtype=jnp.int32)}


# -- facade ------------------------------------------------------------

def test_facade_roundtrip_layout_and_meta(tmp_path):
    params = _params()
    opt = adam.init(params, adam.AdamConfig())
    path = str(tmp_path / "ck")
    ckpt_io.save(path, params, opt, step=42, extra={"arch": "t"})
    # layout: manifest + one shard file for the single rank
    assert os.path.exists(os.path.join(path, "manifest.json"))
    man = ckpt_io.load_manifest(path)
    assert man.step == 42 and man.extra["arch"] == "t"
    assert set(man.groups) == {"params", "opt_state"}
    p2, o2, step = ckpt_io.restore(path, like_params=params, like_opt=opt)
    assert step == 42 and int(o2["step"]) == 0
    for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params)):
        np.testing.assert_array_equal(a, np.asarray(b))
        assert a.dtype == np.asarray(b).dtype   # int32 leaf survives


def test_bfloat16_roundtrip(tmp_path):
    """Regression: npz stores bf16 as raw void ('|V2'); restore must
    reinterpret against the manifest dtype, not hand back garbage.
    Production configs default to param_dtype='bfloat16', so this is
    the dtype real-run checkpoints actually use."""
    params = {"w": jnp.arange(12.0, dtype=jnp.bfloat16).reshape(3, 4),
              "s": jnp.float32(2.0)}
    path = str(tmp_path / "ck")
    ckpt_io.save(path, params, step=7)
    p2, _, step = ckpt_io.restore(path, like_params=params)
    assert step == 7
    assert p2["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(p2["w"], np.float32),
                                  np.asarray(params["w"], np.float32))


def test_bfloat16_cross_shard_reassembly(tmp_path):
    """bf16 must also survive the slow path (slice assembly from
    multiple shard files, not the exact-match member fast path)."""
    full = np.asarray(jnp.arange(16, dtype=jnp.bfloat16).reshape(4, 4))
    shards = (MF.ShardEntry("shard-d00000.npz", "params/w#0",
                            ((0, 2), (0, 4)), 0),
              MF.ShardEntry("shard-d00001.npz", "params/w#0",
                            ((2, 4), (0, 4)), 1))
    entry = MF.LeafEntry((4, 4), "bfloat16", [None, None], shards)
    man = MF.Manifest(step=0, groups={"params": {"w": entry}})
    blobs = {"shard-d00000.npz": {"params/w#0": full[:2]},
             "shard-d00001.npz": {"params/w#0": full[2:]}}
    path = str(tmp_path / "ck")
    sharded.write_snapshot(sharded.Snapshot(man, blobs, {}), path)
    rd = sharded._ShardReader(path)
    got = rd.read(entry, ((1, 3), (0, 4)))       # crosses the boundary
    assert got.dtype == np.dtype("bfloat16")
    np.testing.assert_array_equal(got.astype(np.float32),
                                  full[1:3].astype(np.float32))


def test_snapshot_copies_host_numpy_leaves(tmp_path):
    """The snapshot must capture values at submit time even for plain
    numpy leaves the caller mutates in place afterwards."""
    arr = np.arange(6.0)
    snap = sharded.snapshot({"params": {"x": arr}}, step=0)
    arr *= 100.0
    path = str(tmp_path / "ck")
    sharded.write_snapshot(snap, path)
    got, _, _ = ckpt_io.restore(path)
    np.testing.assert_array_equal(got["x"], np.arange(6.0))


def test_restore_validates_shape_with_keypath(tmp_path):
    path = str(tmp_path / "ck")
    ckpt_io.save(path, _params(), step=1)
    bad = _params()
    bad["layer"]["w"] = jnp.zeros((3, 5))
    with pytest.raises(ValueError, match=r"params\[/layer/w\].*shape"):
        ckpt_io.restore(path, like_params=bad)


def test_restore_validates_dtype_with_keypath(tmp_path):
    """Regression (ISSUE 4 satellite): dtype mismatches used to pass
    silently through restore."""
    path = str(tmp_path / "ck")
    ckpt_io.save(path, _params(), step=1)
    bad = _params()
    bad["blend"] = bad["blend"].astype(jnp.float32)
    with pytest.raises(ValueError, match=r"params\[/blend\].*dtype"):
        ckpt_io.restore(path, like_params=bad)


def test_restore_key_mismatch_lists_paths(tmp_path):
    path = str(tmp_path / "ck")
    ckpt_io.save(path, _params(), step=1)
    with pytest.raises(ValueError, match="key mismatch"):
        ckpt_io.restore(path, like_params={"w": jnp.zeros((3, 3))})


def test_restore_missing_manifest(tmp_path):
    with pytest.raises(FileNotFoundError, match="manifest"):
        ckpt_io.restore(str(tmp_path / "nope"))


# -- manifest ----------------------------------------------------------

def test_spec_serde_roundtrip():
    from jax.sharding import PartitionSpec as P
    for spec in [P(), P(None, "model"), P(("data", "model"), None),
                 P("data", None, "model")]:
        assert MF.spec_from_json(MF.spec_to_json(spec)) == spec


def test_manifest_rejects_foreign_format(tmp_path):
    import json
    path = str(tmp_path / "ck")
    os.makedirs(path)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump({"format": "not-a-ckpt"}, f)
    with pytest.raises(ValueError, match="format"):
        ckpt_io.load_manifest(path)


# -- cross-shard reassembly (the resharding kernel of restore) ---------

def _two_shard_checkpoint(path):
    """Hand-built checkpoint: leaf (4, 4) saved as two row shards, the
    layout an e.g. 2-way mesh would have written."""
    full = np.arange(16, dtype=np.float32).reshape(4, 4)
    shards = (MF.ShardEntry("shard-d00000.npz", "params/w#0",
                            ((0, 2), (0, 4)), 0),
              MF.ShardEntry("shard-d00001.npz", "params/w#0",
                            ((2, 4), (0, 4)), 1))
    entry = MF.LeafEntry((4, 4), "float32", [None, None], shards)
    man = MF.Manifest(step=0, groups={"params": {"w": entry}})
    blobs = {"shard-d00000.npz": {"params/w#0": full[:2]},
             "shard-d00001.npz": {"params/w#0": full[2:]}}
    sharded.write_snapshot(sharded.Snapshot(man, blobs, {}), path)
    return full, entry


def test_reader_reassembles_cross_shard_slices(tmp_path):
    path = str(tmp_path / "ck")
    full, entry = _two_shard_checkpoint(path)
    rd = sharded._ShardReader(path)
    # a slice crossing the shard boundary (what a resharded mesh asks for)
    got = rd.read(entry, ((1, 3), (1, 4)))
    np.testing.assert_array_equal(got, full[1:3, 1:4])
    # exact shard fast path and full read
    np.testing.assert_array_equal(rd.read(entry, ((0, 2), (0, 4))),
                                  full[:2])
    np.testing.assert_array_equal(rd.read(entry, ((0, 4), (0, 4))), full)


def test_reader_detects_coverage_holes(tmp_path):
    path = str(tmp_path / "ck")
    _, entry = _two_shard_checkpoint(path)
    holey = MF.LeafEntry(entry.shape, entry.dtype, entry.spec,
                         entry.shards[:1])     # second shard "lost"
    rd = sharded._ShardReader(path)
    with pytest.raises(ValueError, match="cover"):
        rd.read(holey, ((0, 4), (0, 4)))


def test_reader_overlapping_shards_dont_mask_holes(tmp_path):
    """Coverage is a boolean mask, not a volume sum: two shards that
    overlap each other but leave a hole must still raise, not return
    np.empty garbage in the hole."""
    full = np.arange(16, dtype=np.float32).reshape(4, 4)
    shards = (MF.ShardEntry("shard-d00000.npz", "params/w#0",
                            ((0, 2), (0, 4)), 0),
              MF.ShardEntry("shard-d00000.npz", "params/w#1",
                            ((0, 2), (0, 4)), 0))   # duplicate block
    entry = MF.LeafEntry((4, 4), "float32", [None, None], shards)
    man = MF.Manifest(step=0, groups={"params": {"w": entry}})
    blobs = {"shard-d00000.npz": {"params/w#0": full[:2],
                                  "params/w#1": full[:2]}}
    path = str(tmp_path / "ck")
    sharded.write_snapshot(sharded.Snapshot(man, blobs, {}), path)
    rd = sharded._ShardReader(path)
    with pytest.raises(ValueError, match="cover"):
        rd.read(entry, ((0, 4), (0, 4)))   # rows 2:4 uncovered


def test_reader_missing_shard_file(tmp_path):
    path = str(tmp_path / "ck")
    _, entry = _two_shard_checkpoint(path)
    os.remove(os.path.join(path, "shard-d00001.npz"))
    rd = sharded._ShardReader(path)
    with pytest.raises(FileNotFoundError, match="shard"):
        rd.read(entry, ((0, 4), (0, 4)))


# -- async writer ------------------------------------------------------

class _SlowWriter:
    """Instrumented write_fn: records concurrency and completion, and
    holds the write open until released."""

    def __init__(self, delay=0.0):
        self.delay = delay
        self.active = 0
        self.max_active = 0
        self.done = []
        self._lock = threading.Lock()

    def __call__(self, snap, path):
        with self._lock:
            self.active += 1
            self.max_active = max(self.max_active, self.active)
        time.sleep(self.delay)
        sharded.write_snapshot(snap, path)
        with self._lock:
            self.active -= 1
            self.done.append(path)


def test_async_writer_overlaps_and_snapshots(tmp_path):
    """The save must (a) return while the write is still in flight --
    the caller can keep training -- and (b) capture the values at
    submit time, immune to later in-place updates."""
    slow = _SlowWriter(delay=1.0)
    w = AsyncCheckpointWriter(write_fn=slow)
    params = {"w": jnp.arange(8.0)}
    path = str(tmp_path / "ck")
    w.save(path, {"params": params}, step=3)
    assert w.in_flight                       # returned before the write
    # "one train step" of work completes while the write is in flight
    params = {"w": params["w"] * 2.0}
    jax.block_until_ready(params["w"])
    assert w.in_flight
    w.wait()
    assert not w.in_flight and slow.done == [path]
    got, _, step = ckpt_io.restore(path)
    assert step == 3
    np.testing.assert_array_equal(got["w"], np.arange(8.0))  # pre-mutation


def test_async_writer_in_flight_guard(tmp_path):
    """At most one write in flight: a second save waits for the first,
    and both land completely."""
    slow = _SlowWriter(delay=0.2)
    w = AsyncCheckpointWriter(write_fn=slow)
    p1, p2 = str(tmp_path / "a"), str(tmp_path / "b")
    w.save(p1, {"params": {"x": jnp.zeros(4)}}, step=1)
    w.save(p2, {"params": {"x": jnp.ones(4)}}, step=2)   # guard: waits
    w.wait()
    assert slow.max_active == 1
    assert slow.done == [p1, p2]
    assert ckpt_io.restore(p1)[2] == 1 and ckpt_io.restore(p2)[2] == 2


def test_async_writer_raises_write_errors_at_wait(tmp_path):
    def boom(snap, path):
        raise IOError("disk full")
    w = AsyncCheckpointWriter(write_fn=boom)
    w.save(str(tmp_path / "ck"), {"params": {"x": jnp.zeros(2)}})
    with pytest.raises(IOError, match="disk full"):
        w.wait()
    w.wait()                                  # error consumed; reusable


# -- pipeline cursor ---------------------------------------------------

def test_pipeline_cursor_tracks_and_restores():
    from repro.configs.registry import get_config
    from repro.data.pipeline import make_pipeline
    cfg = get_config("weathermixer-1b").reduced()
    pipe = make_pipeline(cfg, batch_size=2, prefetch=0)
    list(pipe.iterate([1, 1, 1]))
    assert pipe.state() == {"cursor": 3}
    # a fresh pipeline restored to cursor=3 continues the same stream
    fresh = make_pipeline(cfg, batch_size=2, prefetch=0)
    fresh.set_state({"cursor": 3})
    nxt = next(iter(fresh.iterate([2])))
    want = pipe.get(3, 2)
    for k in want:
        np.testing.assert_array_equal(np.asarray(nxt[k]),
                                      np.asarray(want[k]))


# -- engine exact resume (single device) -------------------------------

def test_engine_exact_resume(tmp_path):
    from repro.launch.engine import EngineConfig, TrainEngine
    path = str(tmp_path / "ck")

    def engine(**kw):
        return TrainEngine("internlm2-1.8b",
                           config=EngineConfig(steps=4, batch=2,
                                               seq_len=16, log_every=1,
                                               rollout=2, **kw))

    h_full = engine().run()
    engine(ckpt=path, ckpt_every=2).run()     # checkpoints at step 3
    resumed = engine(resume=path + "-2")
    assert resumed.step_idx == 3
    h_res = resumed.run()
    tail = [h for h in h_full if h["step"] >= 3]
    assert len(h_res) == len(tail) == 1
    assert h_res[0]["loss"] == tail[0]["loss"]
    assert h_res[0]["lr"] == tail[0]["lr"]
    assert h_res[0]["grad_norm"] == tail[0]["grad_norm"]


def test_engine_resume_rejects_schedule_mismatch(tmp_path):
    from repro.launch.engine import EngineConfig, TrainEngine
    path = str(tmp_path / "ck")
    TrainEngine("internlm2-1.8b",
                config=EngineConfig(steps=2, batch=2, seq_len=16,
                                    log_every=1, seed=0, ckpt=path)).run()
    with pytest.raises(ValueError, match="seed"):
        TrainEngine("internlm2-1.8b",
                    config=EngineConfig(steps=2, batch=2, seq_len=16,
                                        log_every=1, seed=1, resume=path))


# -- keep-last-k GC + best marker (ISSUE 5 satellite) ------------------

def test_keep_last_k_ckpt_gc(tmp_path):
    """EngineConfig(keep_ckpts=2): only the newest 2 periodic checkpoint
    dirs survive; the final (non-periodic) checkpoint is never GC'd."""
    from repro.launch.engine import EngineConfig, TrainEngine
    path = str(tmp_path / "ck")
    eng = TrainEngine("weathermixer-1b", config=EngineConfig(
        steps=7, batch=2, log_every=10, ckpt=path, ckpt_every=1,
        keep_ckpts=2, async_save=False))
    eng.run()
    eng.wait_checkpoints()
    have = sorted(p.name for p in tmp_path.iterdir())
    # periodic saves land at ck-1..ck-6; only the last two survive
    assert "ck-5" in have and "ck-6" in have
    assert not any(f"ck-{i}" in have for i in range(1, 5)), have
    assert "ck" in have                      # final save untouched
    # survivors are complete, restorable checkpoints
    from repro import checkpoint as ckpt
    assert ckpt.load_manifest(str(tmp_path / "ck-6")).step == 7


def test_ckpt_gc_spares_best_marker_target(tmp_path):
    """The best-eval marker's checkpoint is exempt from GC."""
    from repro.launch.engine import EngineConfig, TrainEngine
    path = str(tmp_path / "ck")
    eng = TrainEngine("weathermixer-1b", config=EngineConfig(
        steps=8, batch=2, log_every=10, ckpt=path, ckpt_every=2,
        keep_ckpts=1, eval_every=3, eval_batches=1, async_save=False))
    eng.run()
    eng.wait_checkpoints()
    assert eng.best_ckpt is not None
    assert os.path.exists(eng.best_ckpt), (eng.best_ckpt,
                                           sorted(os.listdir(tmp_path)))
    marker = json.load(open(path + "-best.json"))
    assert marker["path"] == eng.best_ckpt
    assert marker["val_loss"] == pytest.approx(eng.best_val)


def test_writer_prunes_only_after_write(tmp_path):
    """AsyncCheckpointWriter.save(prune=...) deletes the old dirs only
    once the new checkpoint is durable (manifest present)."""
    old = tmp_path / "old"
    old.mkdir()
    (old / "x").write_text("stale")
    seen = {}

    def slow_write(snap, path):
        seen["old_alive_during_write"] = old.exists()
        sharded.write_snapshot(snap, path)

    w = AsyncCheckpointWriter(write_fn=slow_write)
    w.save(str(tmp_path / "new"), {"g": {"a": np.arange(4)}},
           prune=[str(old)])
    w.wait()
    assert seen["old_alive_during_write"]    # not pruned before
    assert not old.exists()                  # pruned after
    assert os.path.exists(tmp_path / "new" / "manifest.json")


# -- crash-safe shard writes (ISSUE 7 satellite) -----------------------

def test_shard_writes_are_atomic(tmp_path, monkeypatch):
    """A process killed mid-npz-write must never leave a truncated shard
    at the final name: the payload goes to ``.tmp`` and is renamed into
    place.  Simulated by making the rename step fail."""
    params = {"w": np.arange(8.0)}
    path = str(tmp_path / "ck")

    real_replace = os.replace

    def no_replace(src, dst):
        raise OSError("killed before rename")

    monkeypatch.setattr(os, "replace", no_replace)
    with pytest.raises(OSError, match="killed"):
        sharded.save_checkpoint(path, {"params": params})
    monkeypatch.setattr(os, "replace", real_replace)
    names = sorted(os.listdir(path))
    # only tmp debris, nothing at a final name -> directory reads as torn
    assert all(n.endswith(".tmp") for n in names), names
    assert not sharded.checkpoint_complete(path)

    # a clean write leaves no tmp files behind
    sharded.save_checkpoint(path, {"params": params})
    names = sorted(os.listdir(path))
    assert not any(n.endswith(".tmp") for n in names), names
    assert sharded.checkpoint_complete(path)


def test_manifest_written_last(tmp_path):
    """Ordering contract: every shard file a manifest references exists
    by the time the manifest does (write_snapshot streams shards first)."""
    order = []
    real = sharded._write_npz_atomic

    def spy(fname, members):
        order.append(os.path.basename(fname))
        real(fname, members)

    path = str(tmp_path / "ck")
    snap = sharded.snapshot({"params": {"w": np.arange(4.0)}})
    try:
        sharded._write_npz_atomic = spy
        sharded.write_snapshot(snap, path)
    finally:
        sharded._write_npz_atomic = real
    assert order == ["shard-d00000.npz"]     # shards before manifest.save


# -- writer retry-with-backoff (ISSUE 7 satellite) ---------------------

def test_writer_retries_transient_oserror(tmp_path):
    calls = []

    def flaky(snap, path):
        calls.append(path)
        if len(calls) < 3:
            raise OSError("EIO: nfs blip")
        sharded.write_snapshot(snap, path)

    w = AsyncCheckpointWriter(write_fn=flaky, retry_backoff=0.01)
    path = str(tmp_path / "ck")
    w.save(path, {"params": {"x": np.arange(4.0)}}, step=9)
    w.wait()                                  # no error: 3rd attempt won
    assert len(calls) == 3
    assert ckpt_io.restore(path)[2] == 9


def test_writer_retry_budget_exhausted(tmp_path):
    calls = []

    def always_fails(snap, path):
        calls.append(path)
        raise OSError("disk gone")

    w = AsyncCheckpointWriter(write_fn=always_fails, retries=3,
                              retry_backoff=0.01)
    w.save(str(tmp_path / "ck"), {"params": {"x": np.arange(2.0)}})
    with pytest.raises(OSError, match="disk gone"):
        w.wait()
    assert len(calls) == 3                    # exactly the retry budget


def test_writer_does_not_retry_nontransient_errors(tmp_path):
    calls = []

    def type_bug(snap, path):
        calls.append(path)
        raise ValueError("not weather, a bug")

    w = AsyncCheckpointWriter(write_fn=type_bug, retry_backoff=0.01)
    w.save(str(tmp_path / "ck"), {"params": {"x": np.arange(2.0)}})
    with pytest.raises(ValueError):
        w.wait()
    assert len(calls) == 1


# -- latest_checkpoint discovery (ISSUE 7 satellite) -------------------

def _mini_ckpt(path, step):
    sharded.save_checkpoint(str(path), {"params": {"w": np.arange(4.0)}},
                            step=step)


def test_latest_checkpoint_picks_newest_complete(tmp_path):
    assert sharded.latest_checkpoint(str(tmp_path)) is None  # cold start
    _mini_ckpt(tmp_path / "ck-2", 2)
    _mini_ckpt(tmp_path / "ck-5", 5)
    assert sharded.latest_checkpoint(str(tmp_path)) == \
        str(tmp_path / "ck-5")
    # by manifest STEP, not directory name ordering
    _mini_ckpt(tmp_path / "ck-10", 3)
    assert sharded.latest_checkpoint(str(tmp_path)) == \
        str(tmp_path / "ck-5")


def test_latest_checkpoint_skips_torn_saves(tmp_path):
    _mini_ckpt(tmp_path / "ck-1", 1)
    # torn save A: shards but no manifest (killed before the last write)
    torn = tmp_path / "ck-7"
    torn.mkdir()
    (torn / "shard-d00000.npz").write_bytes(b"partial")
    assert sharded.latest_checkpoint(str(tmp_path)) == \
        str(tmp_path / "ck-1")
    # torn save B: manifest references a shard file that is gone
    _mini_ckpt(tmp_path / "ck-9", 9)
    os.remove(tmp_path / "ck-9" / "shard-d00000.npz")
    assert not sharded.checkpoint_complete(str(tmp_path / "ck-9"))
    assert sharded.latest_checkpoint(str(tmp_path)) == \
        str(tmp_path / "ck-1")
    # torn save C: orphaned per-process index fragments, no manifest
    pod = tmp_path / "ck-11"
    pod.mkdir()
    man = MF.Manifest(step=11, groups={})
    man.save_index(str(pod), 1, 2)
    assert sharded.latest_checkpoint(str(tmp_path)) == \
        str(tmp_path / "ck-1")
    # ...and none of them crash restore discovery or complete-checks
    assert not sharded.checkpoint_complete(str(torn))
    assert not sharded.checkpoint_complete(str(pod))


def test_latest_checkpoint_prefix_filter(tmp_path):
    _mini_ckpt(tmp_path / "ck-3", 3)
    _mini_ckpt(tmp_path / "other-8", 8)
    _mini_ckpt(tmp_path / "ckextra", 9)      # not ck or ck-*: excluded
    assert sharded.latest_checkpoint(str(tmp_path), prefix="ck") == \
        str(tmp_path / "ck-3")
    assert sharded.latest_checkpoint(str(tmp_path), prefix="other") == \
        str(tmp_path / "other-8")
    # root itself can be the checkpoint
    _mini_ckpt(tmp_path / "solo", 1)
    assert sharded.latest_checkpoint(str(tmp_path / "solo")) == \
        str(tmp_path / "solo")


def test_latest_checkpoint_after_engine_gc(tmp_path):
    """Discovery composes with keep-last-k GC + the best marker: what
    the engine leaves behind is exactly what latest_checkpoint ranks,
    and the GC'd dirs are gone, not candidates."""
    from repro.launch.engine import EngineConfig, TrainEngine
    path = str(tmp_path / "ck")
    eng = TrainEngine("weathermixer-1b", config=EngineConfig(
        steps=7, batch=2, log_every=10, ckpt=path, ckpt_every=1,
        keep_ckpts=2, async_save=False))
    eng.run()
    eng.wait_checkpoints()
    # final save (step 7) outranks the surviving periodic ck-5/ck-6
    assert sharded.latest_checkpoint(str(tmp_path), prefix="ck") == path
    # drop the final save: the newest surviving periodic wins
    import shutil
    shutil.rmtree(path)
    assert sharded.latest_checkpoint(str(tmp_path), prefix="ck") == \
        path + "-6"


# -- per-process index merge (pod-scale completeness) ------------------

def _fragment(step, fname, rows, full):
    shard = MF.ShardEntry(fname, "params/w#0", (rows, (0, 4)), 0)
    entry = MF.LeafEntry((4, 4), "float32", [None, None], (shard,))
    man = MF.Manifest(step=step, groups={"params": {"w": entry}})
    return sharded.Snapshot(man, {fname: {"params/w#0":
                                          full[rows[0]:rows[1]]}}, {})


def test_pod_save_merges_index_fragments(tmp_path):
    full = np.arange(16, dtype=np.float32).reshape(4, 4)
    path = str(tmp_path / "ck")
    f0 = _fragment(4, "shard-d00000.npz", (0, 2), full)
    f1 = _fragment(4, "shard-d00001.npz", (2, 4), full)
    # process 1 first: index fragment lands, manifest does not
    sharded.write_snapshot(f1, path, process_index=1, process_count=2)
    assert os.path.exists(os.path.join(path, MF.index_name(1)))
    assert not os.path.exists(os.path.join(path, MF.MANIFEST_NAME))
    assert not sharded.checkpoint_complete(path)
    # process 0: writes, waits for all fragments, merges, finalizes
    sharded.write_snapshot(f0, path, process_index=0, process_count=2)
    assert sharded.checkpoint_complete(path)
    man = ckpt_io.load_manifest(path)
    assert man.step == 4
    assert len(man.groups["params"]["w"].shards) == 2
    got = sharded.restore_tree(path, "params")
    np.testing.assert_array_equal(got["w"], full)


def test_pod_finalize_times_out_on_missing_rank(tmp_path):
    full = np.arange(16, dtype=np.float32).reshape(4, 4)
    path = str(tmp_path / "ck")
    f0 = _fragment(2, "shard-d00000.npz", (0, 2), full)
    os.makedirs(path)
    f0.manifest.save_index(path, 0, 3)
    with pytest.raises(TimeoutError, match="index-p00001"):
        sharded.finalize_checkpoint(path, 3, timeout=0.2, poll=0.02)
    assert not os.path.exists(os.path.join(path, MF.MANIFEST_NAME))


def test_merge_manifests_rejects_torn_pod_save(tmp_path):
    full = np.arange(16, dtype=np.float32).reshape(4, 4)
    f0 = _fragment(2, "shard-d00000.npz", (0, 2), full)
    f1 = _fragment(3, "shard-d00001.npz", (2, 4), full)   # step skew
    with pytest.raises(ValueError, match="torn pod save"):
        MF.merge_manifests([f0.manifest, f1.manifest])


# -- GC prune backlog survives failed/final saves (ISSUE 7 satellite) --

def test_final_save_survives_stale_write_error_and_prunes(tmp_path):
    """A failed async periodic write must not (a) abort the NEXT save --
    in production that next save is the final preemption save -- or (b)
    orphan its GC prune list.  The engine absorbs the stale error at
    save(), re-queues the backlog, re-raises at wait_checkpoints()."""
    from repro.launch.engine import EngineConfig, TrainEngine
    path = str(tmp_path / "ck")
    eng = TrainEngine("weathermixer-1b", config=EngineConfig(
        steps=4, batch=2, log_every=10, ckpt=path, ckpt_every=1,
        keep_ckpts=1))                        # async writer in the loop
    # third periodic write (ck-3) fails after the engine has queued
    # ck-1/ck-2 deletions behind it
    real = sharded.write_snapshot
    calls = []

    def flaky(snap, p, **kw):
        calls.append(p)
        if len(calls) == 3:
            raise OSError("transient EIO")
        return real(snap, p, **kw)

    eng._writer._write_fn = flaky
    eng._writer.retries = 1                   # no writer-level retry
    # the loop must NOT abort mid-run; the absorbed error re-surfaces at
    # run()'s own wait_checkpoints() barrier -- AFTER the final save
    with pytest.raises(OSError, match="EIO"):
        eng.run()
    eng.wait_checkpoints()                    # error consumed exactly once
    # the final save landed despite the stale error...
    assert sharded.checkpoint_complete(path)
    # ...and the prune backlog was drained by it: older periodic dirs
    # are gone (keep_ckpts=1)
    survivors = {n for n in os.listdir(tmp_path)
                 if n.startswith("ck-")
                 and sharded.checkpoint_complete(str(tmp_path / n))}
    assert "ck-1" not in survivors and "ck-2" not in survivors, survivors
    assert sharded.latest_checkpoint(str(tmp_path), prefix="ck") == path


def test_prune_backlog_persisted_and_restored(tmp_path):
    """The backlog rides in manifest extra: a run that dies before its
    deletions execute hands them to the resumed engine."""
    from repro.launch.engine import EngineConfig, TrainEngine
    stale = tmp_path / "ck-0"
    stale.mkdir()
    path = str(tmp_path / "ck")
    eng = TrainEngine("internlm2-1.8b", config=EngineConfig(
        steps=2, batch=2, seq_len=16, log_every=1, ckpt=path,
        async_save=False))
    eng._prune_backlog = [str(stale)]
    eng.run()
    man = ckpt_io.load_manifest(path)
    # the final save drained the backlog (dir deleted) and recorded it
    assert not stale.exists()
    assert man.extra["prune_backlog"] == [str(stale)]
    # a resumed engine drops already-deleted entries
    res = TrainEngine("internlm2-1.8b", config=EngineConfig(
        steps=2, batch=2, seq_len=16, log_every=1, resume=path))
    assert res._prune_backlog == []


# -- multi-device: sharded save + resharded restore --------------------

def test_ckpt_sharded_reshard_scenario():
    """16 emulated devices in a subprocess: per-rank byte accounting
    (no full-model gather) + save-on-8-way / restore-on-4-way."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    env.pop("JAX_PLATFORMS", None)
    res = subprocess.run(
        [sys.executable, os.path.join(HERE, "dist_scenarios.py"),
         "ckpt_sharded_reshard"],
        env=env, capture_output=True, text=True, timeout=600)
    assert res.returncode == 0 and "ALL-OK" in res.stdout, (
        f"\nstdout:\n{res.stdout[-3000:]}\nstderr:\n{res.stderr[-3000:]}")
