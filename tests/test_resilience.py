"""Fault-tolerant elastic training (ISSUE 7, DESIGN.md §12): the
PreemptionHandler signal choreography, the Supervisor relaunch loop,
engine preempt -> final synchronous save -> exact resume, pipeline
shutdown hardening, the ``--supervise`` CLI end-to-end, and the two
chaos dist scenarios (``preempt_resume_exact``,
``elastic_reshard_resume``) via subprocess."""
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.checkpoint import sharded
from repro.launch import resilience
from repro.launch.engine import EngineConfig, TrainEngine

HERE = os.path.dirname(__file__)
SRC = os.path.abspath(os.path.join(HERE, "..", "src"))


# -- PreemptionHandler -------------------------------------------------

def test_handler_catches_sigterm_and_restores_previous():
    prev = signal.getsignal(signal.SIGTERM)
    h = resilience.PreemptionHandler().install()
    try:
        assert h.installed and not h.should_stop
        os.kill(os.getpid(), signal.SIGTERM)
        assert h.should_stop and h.received == signal.SIGTERM
    finally:
        h.uninstall()
    assert signal.getsignal(signal.SIGTERM) == prev
    assert not h.installed


def test_handler_catches_sigusr1():
    with resilience.PreemptionHandler() as h:
        os.kill(os.getpid(), signal.SIGUSR1)
        assert h.should_stop and h.received == signal.SIGUSR1


def test_handler_chaos_hook_delivers_real_signal():
    """poll(step) at the armed step must go through the REAL signal
    path (os.kill on ourselves), not just flip a flag."""
    with resilience.PreemptionHandler(preempt_at_step=2) as h:
        assert not h.poll(0)
        assert not h.poll(1)
        assert h.poll(2)
        assert h.received == signal.SIGTERM   # a real delivered signal
        assert h.poll(3)                      # latched


def test_handler_reads_chaos_env(monkeypatch):
    monkeypatch.setenv(resilience.PREEMPT_ENV, "5")
    assert resilience.PreemptionHandler().preempt_at_step == 5
    # explicit argument beats the env
    assert resilience.PreemptionHandler(
        preempt_at_step=1).preempt_at_step == 1
    monkeypatch.delenv(resilience.PREEMPT_ENV)
    assert resilience.PreemptionHandler().preempt_at_step is None


def test_handler_non_main_thread_degrades_to_inert():
    import threading
    out = {}

    def worker():
        with pytest.warns(UserWarning, match="main thread"):
            h = resilience.PreemptionHandler().install()
        out["installed"] = h.installed
        out["poll"] = h.poll(0)

    t = threading.Thread(target=worker)
    t.start()
    t.join()
    assert out == {"installed": False, "poll": False}


# -- Supervisor --------------------------------------------------------

def test_supervisor_resumable_exit_restarts_immediately():
    rcs = iter([resilience.RESUMABLE_EXIT_CODE, 0])
    sleeps = []
    sup = resilience.Supervisor(
        lambda resume, attempt: ["train", str(attempt)],
        run_cmd=lambda argv: next(rcs), sleep_fn=sleeps.append)
    assert sup.run() == 0
    assert sup.attempts == [resilience.RESUMABLE_EXIT_CODE, 0]
    assert sleeps == []                       # no backoff on preemption


def test_supervisor_crash_backoff_is_exponential():
    rcs = iter([1, 1, 1, 0])
    sleeps = []
    sup = resilience.Supervisor(
        lambda resume, attempt: ["train"], max_restarts=5, backoff=1.0,
        run_cmd=lambda argv: next(rcs), sleep_fn=sleeps.append)
    assert sup.run() == 0
    assert len(sleeps) == 3
    # delay doubles each crash; jitter adds up to +25%
    assert 1.0 <= sleeps[0] <= 1.25
    assert 2.0 <= sleeps[1] <= 2.5
    assert 4.0 <= sleeps[2] <= 5.0


def test_supervisor_gives_up_after_max_restarts():
    sup = resilience.Supervisor(
        lambda resume, attempt: ["train"], max_restarts=2, backoff=0.0,
        run_cmd=lambda argv: 1, sleep_fn=lambda s: None)
    assert sup.run() == 1
    assert sup.attempts == [1, 1, 1]          # initial + 2 restarts


def test_supervisor_rediscovers_latest_checkpoint(tmp_path):
    """The resume point is rediscovered before EVERY launch -- a
    checkpoint written by the first (preempted) child is what the
    second child resumes from."""
    launched = []

    def run_cmd(argv):
        if not launched:
            launched.append(argv)
            sharded.save_checkpoint(
                str(tmp_path / "ck-3"), {"g": {"x": np.arange(2.0)}},
                step=3)
            return resilience.RESUMABLE_EXIT_CODE
        launched.append(argv)
        return 0

    sup = resilience.Supervisor(
        lambda resume, attempt: ["train"] + (["--resume", resume]
                                             if resume else []),
        ckpt_root=str(tmp_path), prefix="ck", run_cmd=run_cmd)
    assert sup.run() == 0
    assert sup.resumes == [None, str(tmp_path / "ck-3")]
    assert launched[1][-2:] == ["--resume", str(tmp_path / "ck-3")]


def test_supervisor_skips_torn_checkpoints(tmp_path):
    torn = tmp_path / "ck-9"
    torn.mkdir()
    (torn / "shard-d00000.npz").write_bytes(b"partial")   # no manifest
    sharded.save_checkpoint(str(tmp_path / "ck-2"),
                            {"g": {"x": np.arange(2.0)}}, step=2)
    sup = resilience.Supervisor(lambda r, a: ["train"],
                                ckpt_root=str(tmp_path), prefix="ck",
                                run_cmd=lambda argv: 0)
    sup.run()
    assert sup.resumes == [str(tmp_path / "ck-2")]


def test_strip_args():
    argv = ["--arch", "a", "--supervise", "--max-restarts", "5",
            "--resume=old", "--steps", "3"]
    assert resilience.strip_args(
        argv, flags=("--supervise",), valued=("--max-restarts",
                                              "--resume")) == \
        ["--arch", "a", "--steps", "3"]


# -- engine preempt -> final save -> resume (single device) ------------

def test_engine_preempt_finalize_and_exact_resume(tmp_path):
    path = str(tmp_path / "ck")
    mfile = str(tmp_path / "m.json")

    def engine(**kw):
        return TrainEngine("internlm2-1.8b", config=EngineConfig(
            steps=4, batch=2, seq_len=16, log_every=1, **kw))

    h_full = engine().run()

    prev = signal.getsignal(signal.SIGTERM)
    eng = engine(ckpt=path, preempt_at_step=1, metrics_out=mfile)
    with pytest.raises(resilience.Preempted) as ei:
        eng.run()
    assert signal.getsignal(signal.SIGTERM) == prev   # handler restored
    assert ei.value.step == 2                 # the in-flight step finished
    assert ei.value.checkpoint == path + "-1"
    assert ei.value.signum == signal.SIGTERM
    assert sharded.checkpoint_complete(path + "-1")
    assert eng.preempt_stats["step"] == 1
    assert eng.preempt_stats["final_save_s"] > 0
    import json
    with open(mfile) as f:
        logged = [json.loads(line) for line in f if line.strip()]
    assert [h["step"] for h in logged] == [0, 1]   # metrics persisted

    resumed = engine(resume=path + "-1")
    assert resumed.step_idx == 2
    assert resumed.pipeline.cursor == 2
    h_res = resumed.run()
    tail = [h for h in h_full if h["step"] >= 2]
    assert len(h_res) == len(tail) == 2
    for a, b in zip(tail, h_res):
        assert a["loss"] == b["loss"]
        assert a["lr"] == b["lr"]
        assert a["grad_norm"] == b["grad_norm"]


def test_engine_preempt_without_ckpt_still_exits_orderly():
    eng = TrainEngine("internlm2-1.8b", config=EngineConfig(
        steps=3, batch=2, seq_len=16, log_every=1, preempt_at_step=0))
    with pytest.raises(resilience.Preempted) as ei:
        eng.run()
    assert ei.value.checkpoint is None and ei.value.step == 1


# -- pipeline shutdown hardening ---------------------------------------

def test_pipeline_stop_cancels_mid_prefetch():
    from repro.configs.registry import get_config
    from repro.data.pipeline import make_pipeline
    cfg = get_config("weathermixer-1b").reduced()
    pipe = make_pipeline(cfg, batch_size=2, prefetch=2)
    it = pipe.iterate([1] * 200)
    next(it)                                  # worker is prefetching ahead
    assert pipe._thread is not None and pipe._thread.daemon
    t0 = time.time()
    assert pipe.stop(timeout=5.0)             # cancels promptly...
    assert time.time() - t0 < 5.0             # ...without the full horizon
    assert pipe._thread is None
    assert pipe.stop()                        # idempotent no-op


def test_pipeline_stop_noop_without_prefetch():
    from repro.configs.registry import get_config
    from repro.data.pipeline import make_pipeline
    cfg = get_config("weathermixer-1b").reduced()
    pipe = make_pipeline(cfg, batch_size=2, prefetch=0)
    list(pipe.iterate([1, 1]))
    assert pipe.stop()                        # nothing to join


def test_pipeline_iterate_still_exact_after_stop_resume():
    """stop() mid-stream + a fresh iterate from the cursor reproduces
    the uninterrupted stream (determinism is cursor-only state)."""
    from repro.configs.registry import get_config
    from repro.data.pipeline import make_pipeline
    cfg = get_config("weathermixer-1b").reduced()
    ref = make_pipeline(cfg, batch_size=2, prefetch=0)
    want = [ref.get(i, 1) for i in range(4)]

    pipe = make_pipeline(cfg, batch_size=2, prefetch=2)
    it = pipe.iterate([1] * 4)
    got = [next(it), next(it)]
    pipe.stop()
    got += list(pipe.iterate([1] * 2))        # continues from cursor=2
    for g, w in zip(got, want):
        for k in w:
            np.testing.assert_array_equal(np.asarray(g[k]),
                                          np.asarray(w[k]))


# -- CLI: --supervise end-to-end ---------------------------------------

def test_cli_supervise_preempt_and_resume(tmp_path):
    """Full stack in subprocesses: child 0 self-SIGTERMs after step 0
    (chaos env), exits 75 with a durable checkpoint; the supervisor
    relaunches with --resume; child 1 finishes; overall rc == 0."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env[resilience.PREEMPT_ENV] = "0"
    env.setdefault("JAX_PLATFORMS", "cpu")
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "internlm2-1.8b", "--steps", "2", "--batch", "2",
         "--seq-len", "16", "--log-every", "1",
         "--ckpt", str(tmp_path / "ck"),
         "--supervise", "--max-restarts", "2"],
        env=env, capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, (
        f"\nstdout:\n{res.stdout[-3000:]}\nstderr:\n{res.stderr[-3000:]}")
    assert "resumable exit" in res.stdout     # supervisor saw code 75
    assert "[preempt]" in res.stdout          # child ran the final save
    assert sharded.latest_checkpoint(str(tmp_path), prefix="ck") == \
        str(tmp_path / "ck")                  # final save outranks ck-0


def test_cli_supervise_requires_ckpt():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "internlm2-1.8b", "--steps", "1", "--supervise"],
        env=env, capture_output=True, text=True, timeout=120)
    assert res.returncode != 0
    assert "--supervise requires --ckpt" in res.stderr


# -- chaos dist scenarios (16 emulated devices, subprocess) ------------

def _run_scenario(name, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    env.pop("JAX_PLATFORMS", None)
    env.pop(resilience.PREEMPT_ENV, None)
    res = subprocess.run(
        [sys.executable, os.path.join(HERE, "dist_scenarios.py"), name],
        env=env, capture_output=True, text=True, timeout=timeout)
    assert res.returncode == 0 and "ALL-OK" in res.stdout, (
        f"\nstdout:\n{res.stdout[-3000:]}\nstderr:\n{res.stderr[-3000:]}")


def test_preempt_resume_exact_scenario():
    """SIGTERM mid-run -> supervisor restart -> bit-identical history."""
    _run_scenario("preempt_resume_exact")


def test_elastic_reshard_resume_scenario():
    """8-way save resumes on a 4-way mesh with zero1 refit + pod-scale
    per-process index completeness."""
    _run_scenario("elastic_reshard_resume")
