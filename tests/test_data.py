"""Data pipeline: the domain-parallel loading invariant (paper §5) --
``sample_shard`` == full sample sliced -- plus determinism properties."""
import itertools

import numpy as np
import pytest

try:
    import hypothesis
    from hypothesis import given, settings, strategies as st
    # conftest.py installs a deterministic stand-in when hypothesis is
    # missing; prefer the exhaustive parametrize grid below over the
    # stub's 10 pseudo-random draws.
    HAVE_HYPOTHESIS = not getattr(hypothesis, "__stub__", False)
except ImportError:          # pragma: no cover - environment-dependent
    HAVE_HYPOTHESIS = False

from repro.data.tokens import TokenDataConfig, TokenDataset
from repro.data.weather import WeatherDataConfig, WeatherDataset

CFG = WeatherDataConfig(lat=16, lon=32, channels=6, seed=7)


def _check_shard_equals_full_slice(step, lon0, nlon, ch0, nch):
    """Every model-parallel rank's partitioned read is bit-identical to
    slicing the full sample -- the paper's data-loading correctness."""
    ds = WeatherDataset(CFG)
    lon_sl = slice(lon0 * 8, lon0 * 8 + nlon * 8)
    ch_sl = slice(ch0, ch0 + nch)
    full = ds.sample_batch(step, 2)
    shard = ds.sample_shard(step, 2, lon_slice=lon_sl, chan_slice=ch_sl)
    np.testing.assert_array_equal(shard["fields"],
                                  full["fields"][:, :, lon_sl, ch_sl])
    np.testing.assert_array_equal(shard["target"],
                                  full["target"][:, :, lon_sl, ch_sl])


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(step=st.integers(0, 5),
           lon0=st.integers(0, 3), nlon=st.integers(1, 4),
           ch0=st.integers(0, 2), nch=st.integers(1, 3))
    def test_weather_shard_equals_full_slice(step, lon0, nlon, ch0, nch):
        _check_shard_equals_full_slice(step, lon0, nlon, ch0, nch)
else:
    @pytest.mark.parametrize(
        "step,lon0,nlon,ch0,nch",
        list(itertools.product((0, 3), (0, 3), (1, 4), (0, 2), (1, 3))))
    def test_weather_shard_equals_full_slice(step, lon0, nlon, ch0, nch):
        _check_shard_equals_full_slice(step, lon0, nlon, ch0, nch)


def test_weather_deterministic_and_distinct():
    ds = WeatherDataset(CFG)
    a = ds.sample_batch(3, 2)
    b = ds.sample_batch(3, 2)
    c = ds.sample_batch(4, 2)
    np.testing.assert_array_equal(a["fields"], b["fields"])
    assert not np.allclose(a["fields"], c["fields"])
    # target differs from input (there is something to learn)
    assert not np.allclose(a["fields"], a["target"])


def test_weather_io_bytes_model():
    ds = WeatherDataset(CFG)
    full = ds.io_bytes_per_rank(4, 1)
    quarter = ds.io_bytes_per_rank(4, 4)
    assert full == 4 * quarter  # domain parallelism divides I/O by n


def test_tokens_deterministic_learnable():
    ds = TokenDataset(TokenDataConfig(vocab_size=97, seq_len=64, seed=1))
    a = ds.sample_batch(0, 4)
    b = ds.sample_batch(0, 4)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (4, 64)
    assert a["labels"].shape == (4, 64)
    # the affine-walk structure: most next-tokens follow (31x+17) % V
    pred = (a["tokens"] * 31 + 17) % 97
    frac = (pred == a["labels"]).mean()
    assert frac > 0.8
    assert a["tokens"].max() < 97 and a["tokens"].min() >= 0
