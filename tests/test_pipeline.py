"""Input-pipeline subsystem: sharded reads == full batches (incl. the
rollout-horizon fix), prefetcher determinism, engine step dispatch, and
I/O accounting.  Multi-device variants live in dist_scenarios.py
(``input_pipeline`` / ``engine_pipeline``), run via test_distributed."""
import numpy as np
import pytest

from repro.data.pipeline import (InputPipeline, TokenBatchSource,
                                 WeatherBatchSource, make_pipeline)
from repro.data.tokens import TokenDataConfig, TokenDataset
from repro.data.weather import WeatherDataConfig, WeatherDataset

WCFG = WeatherDataConfig(lat=16, lon=32, channels=6, seed=3)


# -- dataset-level sharded reads ---------------------------------------

@pytest.mark.parametrize("horizon", [1, 2, 4])
def test_weather_shard_respects_horizon(horizon):
    """Regression: sample_shard used to hardcode t = dt_phase, breaking
    shard == full-slice for rollout fine-tuning targets."""
    ds = WeatherDataset(WCFG)
    full = ds.sample_batch(2, 4, horizon=horizon)
    shard = ds.sample_shard(2, 4, lon_slice=slice(8, 24),
                            chan_slice=slice(1, 5), row_slice=slice(1, 3),
                            lat_slice=slice(4, 12), horizon=horizon)
    np.testing.assert_array_equal(
        shard["fields"], full["fields"][1:3, 4:12, 8:24, 1:5])
    np.testing.assert_array_equal(
        shard["target"], full["target"][1:3, 4:12, 8:24, 1:5])


def test_token_shard_equals_row_slice():
    ds = TokenDataset(TokenDataConfig(vocab_size=97, seq_len=48, seed=5))
    full = ds.sample_batch(7, 8)
    shard = ds.sample_shard(7, 8, row_slice=slice(2, 6))
    np.testing.assert_array_equal(shard["tokens"], full["tokens"][2:6])
    np.testing.assert_array_equal(shard["labels"], full["labels"][2:6])
    # io model: row sharding divides the read
    assert ds.io_bytes_per_rank(8, 4) * 4 == ds.io_bytes_per_rank(8, 1)


# -- source adapters ----------------------------------------------------

def test_weather_source_read_key_matches_full():
    src = WeatherBatchSource(WeatherDataset(WCFG), batch_size=4)
    full = src.full_batch(1, 3)
    idx = ((0, 2), (0, 16), (8, 24), (2, 5))
    for key in src.keys:
        got = src.read_key(key, 1, 3, idx)
        np.testing.assert_array_equal(got, full[key][0:2, :, 8:24, 2:5])
    assert src.key_shape("fields") == (4, 16, 32, 6)


def test_token_source_extras_sliced_from_full_draw():
    ds = TokenDataset(TokenDataConfig(vocab_size=64, seq_len=16, seed=1))
    src = TokenBatchSource(ds, batch_size=4, extras={"embeds": (8, 32)})
    full = src.full_batch(2, 1)
    assert set(src.keys) == {"tokens", "labels", "embeds"}
    got = src.read_key("embeds", 2, 1, ((1, 3), (0, 8), (16, 32)))
    np.testing.assert_array_equal(got, full["embeds"][1:3, :, 16:32])
    rows = src.read_key("tokens", 2, 1, ((1, 3), (0, 16)))
    np.testing.assert_array_equal(rows, full["tokens"][1:3])
    # regression: the extras memo must roll over with the step on the
    # full-batch path too (they used to freeze at the first step)
    assert not np.array_equal(full["embeds"], src.full_batch(3, 1)["embeds"])


# -- pipeline (single device: mesh=None) --------------------------------

def test_pipeline_no_mesh_roundtrip():
    from repro.configs.registry import get_config
    cfg = get_config("weathermixer-1b").reduced()
    pipe = make_pipeline(cfg, batch_size=2, mode="sharded", prefetch=0)
    got = pipe.get(0, 2)
    want = pipe.host_batch(0, 2)
    for k in want:
        np.testing.assert_array_equal(np.asarray(got[k]), want[k])


def test_pipeline_prefetch_deterministic():
    from repro.configs.registry import get_config
    cfg = get_config("weathermixer-1b").reduced()
    sync = make_pipeline(cfg, batch_size=2, prefetch=0)
    pref = make_pipeline(cfg, batch_size=2, prefetch=2)
    horizons = [1, 3, 2, 1, 2]
    got = list(pref.iterate(horizons))
    assert len(got) == len(horizons)
    for i, h in enumerate(horizons):
        want = sync.get(i, h)
        for k in want:
            np.testing.assert_array_equal(np.asarray(got[i][k]),
                                          np.asarray(want[k]))


def test_pipeline_prefetch_propagates_errors():
    class Boom(WeatherBatchSource):
        def full_batch(self, step, horizon):
            if step >= 2:
                raise RuntimeError("disk on fire")
            return super().full_batch(step, horizon)

    src = Boom(WeatherDataset(WCFG), batch_size=2)
    pipe = InputPipeline(src, prefetch=2)
    it = pipe.iterate([1, 1, 1, 1])
    next(it), next(it)
    with pytest.raises(RuntimeError, match="disk on fire"):
        for _ in it:
            pass


def test_pipeline_rejects_bad_mode():
    src = WeatherBatchSource(WeatherDataset(WCFG), batch_size=2)
    with pytest.raises(ValueError):
        InputPipeline(src, mode="async-magic")


# -- engine (single device) ---------------------------------------------

def test_engine_matches_legacy_format_and_evaluates(tmp_path):
    from repro.launch.engine import EngineConfig, TrainEngine
    eng = TrainEngine("internlm2-1.8b",
                      config=EngineConfig(steps=6, batch=4, seq_len=32,
                                          log_every=5, lr=2e-3,
                                          eval_batches=1))
    hist = eng.run()
    assert {"loss", "lr", "step", "wall_s"} <= set(hist[0])
    assert np.isfinite(hist[-1]["loss"])
    em = eng.evaluate()
    assert np.isfinite(em["val_loss"])
    # checkpoint hook (async by default: wait_checkpoints is the barrier)
    path = str(tmp_path / "ck")
    eng.save(path)
    eng.wait_checkpoints()
    from repro.checkpoint import io as ckpt_io
    import jax
    from repro.models import registry as M
    like = M.init(jax.random.PRNGKey(0), eng.cfg)
    _, _, step = ckpt_io.restore(path, like_params=like)
    assert step == 6


def test_engine_accum_close_to_full_batch():
    from repro.launch.engine import EngineConfig, TrainEngine

    def final(accum):
        eng = TrainEngine("internlm2-1.8b",
                          config=EngineConfig(steps=3, batch=4, seq_len=32,
                                              log_every=2, accum=accum))
        return eng.run()[-1]["loss"]

    assert np.allclose(final(1), final(2), rtol=1e-4)
